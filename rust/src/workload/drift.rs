//! Demand-side drift: time-varying workload schedules and online mixture
//! estimation.
//!
//! The paper's plan is cost-optimal only for the mixture it was solved
//! against; Mélange (Griggs et al.) shows the GPU composition should be
//! re-decided as the request-size mixture shifts. This module supplies the
//! demand half of the orchestrator's world signal:
//!
//! * [`MixSchedule`] — a piecewise-linear time-varying ([`TraceMix`],
//!   arrival-rate) pair, the *ground truth* demand process a scenario
//!   replays (mixture shifts, diurnal rate ramps);
//! * [`DemandSnapshot`] — one observation of that process (rate + mixture),
//!   the demand channel of [`crate::cloud::WorldEvent`];
//! * [`demand_drift`] — the scale-invariant distance between two snapshots
//!   that the replanner thresholds on;
//! * [`MixEstimator`] — an exponentially-weighted online estimator over
//!   *observed* arrivals, so the closed loop can replan against estimated
//!   (not oracle) demand.

// Determinism-zone lint policy (mirrors pallas-lint rule P001): no
// unwrap() outside tests - use expect("invariant") or propagate.
#![cfg_attr(not(test), warn(clippy::unwrap_used))]

use super::{Trace, TraceMix};

/// One observation of the demand process: aggregate arrival rate plus the
/// mixture over the nine workload types.
#[derive(Clone, Debug, PartialEq)]
pub struct DemandSnapshot {
    /// Aggregate arrival rate, requests/second.
    pub rate_rps: f64,
    /// Mixture over workload types 1..9.
    pub mix: TraceMix,
}

impl DemandSnapshot {
    pub fn new(rate_rps: f64, mix: TraceMix) -> DemandSnapshot {
        DemandSnapshot { rate_rps, mix }
    }

    /// Request demand per workload type over a planning epoch of
    /// `epoch_s` seconds.
    pub fn demands_over(&self, epoch_s: f64) -> [f64; 9] {
        self.mix.demands(self.rate_rps * epoch_s)
    }
}

/// Normalised demand drift between two snapshots: total-variation distance
/// of the mixtures plus the relative rate change. Zero for identical
/// snapshots; invariant under scaling both rates by the same factor (the
/// metric reacts to the *shape* of demand, and to rate changes only in
/// relative terms). Each term lies in [0, 1], so the sum is in [0, 2] —
/// the same scale as [`crate::orchestrator::market_drift`]'s supply axis.
pub fn demand_drift(old: &DemandSnapshot, new: &DemandSnapshot) -> f64 {
    let mix_term = old.mix.total_variation(&new.mix);
    let denom = old.rate_rps.max(new.rate_rps);
    let rate_term = if denom > 0.0 {
        (old.rate_rps - new.rate_rps).abs() / denom
    } else {
        0.0
    };
    mix_term + rate_term
}

/// One keyframe of a demand schedule: the mixture and rate in force at
/// `t_s`, linearly interpolated toward the next keyframe.
#[derive(Clone, Debug)]
pub struct MixKeyframe {
    pub t_s: f64,
    pub mix: TraceMix,
    pub rate_rps: f64,
}

/// A piecewise-linear time-varying demand process: `TraceMix` ratios and
/// the aggregate arrival rate are both interpolated between keyframes
/// (clamped to the first/last keyframe outside their span). Because the
/// rate is piecewise linear, its maximum over any horizon is attained at a
/// keyframe — which is what lets [`super::synthesize_trace_schedule`] use
/// exact Poisson thinning.
#[derive(Clone, Debug)]
pub struct MixSchedule {
    pub name: String,
    keyframes: Vec<MixKeyframe>,
}

impl MixSchedule {
    /// Build from keyframes. Rejects empty lists, unsorted or non-finite
    /// times, and negative rates.
    pub fn new(name: &str, keyframes: Vec<MixKeyframe>) -> anyhow::Result<MixSchedule> {
        if keyframes.is_empty() {
            anyhow::bail!("schedule '{name}' has no keyframes");
        }
        for k in &keyframes {
            if !k.t_s.is_finite() || !k.rate_rps.is_finite() || k.rate_rps < 0.0 {
                anyhow::bail!(
                    "schedule '{name}': bad keyframe (t={}, rate={})",
                    k.t_s,
                    k.rate_rps
                );
            }
        }
        for w in keyframes.windows(2) {
            if w[1].t_s < w[0].t_s {
                anyhow::bail!(
                    "schedule '{name}': keyframes out of order ({} after {})",
                    w[1].t_s,
                    w[0].t_s
                );
            }
        }
        Ok(MixSchedule {
            name: name.to_string(),
            keyframes,
        })
    }

    /// A stationary schedule: one mixture, one rate, forever.
    pub fn constant(mix: TraceMix, rate_rps: f64) -> MixSchedule {
        let name = format!("const-{}", mix.name);
        MixSchedule::new(
            &name,
            vec![MixKeyframe {
                t_s: 0.0,
                mix,
                rate_rps,
            }],
        )
        .expect("constant schedule is always valid")
    }

    /// The canonical drift scenario: hold `(from_mix, from_rate)` until
    /// `ramp_start_s`, linearly shift to `(to_mix, to_rate)` by
    /// `ramp_end_s`, then hold.
    pub fn shift(
        name: &str,
        from: (TraceMix, f64),
        to: (TraceMix, f64),
        ramp_start_s: f64,
        ramp_end_s: f64,
    ) -> anyhow::Result<MixSchedule> {
        if ramp_end_s < ramp_start_s {
            anyhow::bail!(
                "schedule '{name}': ramp ends ({ramp_end_s}) before it starts ({ramp_start_s})"
            );
        }
        let (from_mix, from_rate) = from;
        let (to_mix, to_rate) = to;
        MixSchedule::new(
            name,
            vec![
                MixKeyframe {
                    t_s: ramp_start_s,
                    mix: from_mix,
                    rate_rps: from_rate,
                },
                MixKeyframe {
                    t_s: ramp_end_s,
                    mix: to_mix,
                    rate_rps: to_rate,
                },
            ],
        )
    }

    /// Arrival rate at time `t_s` (requests/second).
    pub fn rate_at(&self, t_s: f64) -> f64 {
        match self.bracket(t_s) {
            Bracket::Before(k) | Bracket::After(k) => k.rate_rps,
            Bracket::Between(a, b, alpha) => a.rate_rps + alpha * (b.rate_rps - a.rate_rps),
        }
    }

    /// Mixture at time `t_s`: ratios linearly interpolated between the
    /// bracketing keyframes and renormalised (FP-safe via
    /// [`TraceMix::normalized`]).
    pub fn mix_at(&self, t_s: f64) -> TraceMix {
        match self.bracket(t_s) {
            Bracket::Before(k) | Bracket::After(k) => k.mix.clone(),
            Bracket::Between(a, b, alpha) => {
                let mut ratios = [0.0; 9];
                for (i, r) in ratios.iter_mut().enumerate() {
                    *r = a.mix.ratios[i] + alpha * (b.mix.ratios[i] - a.mix.ratios[i]);
                }
                TraceMix::normalized(&self.name, ratios)
                    .expect("interpolation of valid mixes stays valid")
            }
        }
    }

    /// The full demand snapshot at time `t_s`.
    pub fn at(&self, t_s: f64) -> DemandSnapshot {
        DemandSnapshot {
            rate_rps: self.rate_at(t_s),
            mix: self.mix_at(t_s),
        }
    }

    /// Maximum arrival rate over the whole schedule. Piecewise linearity
    /// puts the max at a keyframe, so this bounds `rate_at` everywhere —
    /// the thinning envelope of the non-stationary trace synthesizer.
    pub fn max_rate(&self) -> f64 {
        self.keyframes.iter().map(|k| k.rate_rps).fold(0.0, f64::max)
    }

    fn bracket(&self, t_s: f64) -> Bracket<'_> {
        let first = self.keyframes.first().expect("schedule is non-empty");
        if t_s <= first.t_s {
            return Bracket::Before(first);
        }
        for w in self.keyframes.windows(2) {
            let (a, b) = (&w[0], &w[1]);
            if t_s <= b.t_s {
                let span = b.t_s - a.t_s;
                let alpha = if span > 0.0 { (t_s - a.t_s) / span } else { 1.0 };
                return Bracket::Between(a, b, alpha);
            }
        }
        Bracket::After(self.keyframes.last().expect("schedule is non-empty"))
    }
}

enum Bracket<'a> {
    Before(&'a MixKeyframe),
    Between(&'a MixKeyframe, &'a MixKeyframe, f64),
    After(&'a MixKeyframe),
}

/// Exponentially-weighted online estimator of the demand process from
/// observed arrivals. Every observation carries weight 1 at its arrival
/// time and decays with the configured half-life; the mixture estimate is
/// the normalised decayed per-type mass, and the rate estimate uses the
/// steady-state identity E[mass] = λ/k for a Poisson process observed
/// through an exponential window with decay constant k.
///
/// Until enough mass has accumulated (a few requests), `snapshot` falls
/// back to the prior it was constructed with, so a cold-started closed
/// loop plans against the same demand a static planner would.
#[derive(Clone, Debug)]
pub struct MixEstimator {
    halflife_s: f64,
    counts: [f64; 9],
    total: f64,
    last_t_s: f64,
    /// Time of the first observation — the start of the window the decayed
    /// mass actually covers, used to bias-correct the rate estimate.
    start_t_s: Option<f64>,
    prior: DemandSnapshot,
}

/// Decayed observation mass below which the estimator reports its prior.
const MIN_ESTIMATOR_MASS: f64 = 5.0;

impl MixEstimator {
    pub fn new(halflife_s: f64, prior: DemandSnapshot) -> MixEstimator {
        assert!(
            halflife_s.is_finite() && halflife_s > 0.0,
            "estimator half-life must be positive, got {halflife_s}"
        );
        MixEstimator {
            halflife_s,
            counts: [0.0; 9],
            total: 0.0,
            last_t_s: 0.0,
            start_t_s: None,
            prior,
        }
    }

    /// Record one observed arrival of workload type `workload` at `t_s`.
    /// Out-of-order arrivals are tolerated (decay never runs backwards).
    pub fn observe(&mut self, t_s: f64, workload: usize) {
        if self.start_t_s.is_none() {
            self.start_t_s = Some(t_s);
            self.last_t_s = t_s;
        }
        self.decay_to(t_s);
        self.counts[workload] += 1.0;
        self.total += 1.0;
    }

    /// Feed every arrival of `trace` with `from_s <= arrival < to_s` —
    /// the causal window a closed loop observes between two replans.
    /// Arrivals are sorted, so the window is located by binary search.
    pub fn observe_trace_window(&mut self, trace: &Trace, from_s: f64, to_s: f64) {
        let start = trace.requests.partition_point(|r| r.arrival_s < from_s);
        for r in &trace.requests[start..] {
            if r.arrival_s >= to_s {
                break;
            }
            self.observe(r.arrival_s, r.workload.index);
        }
    }

    /// Decayed observation mass currently held (diagnostic).
    pub fn mass(&self) -> f64 {
        self.total
    }

    /// The demand estimate as of `t_s`.
    pub fn snapshot(&mut self, t_s: f64) -> DemandSnapshot {
        self.decay_to(t_s);
        if self.total < MIN_ESTIMATOR_MASS {
            return self.prior.clone();
        }
        let mix = TraceMix::normalized("estimated", self.counts)
            .expect("positive mass normalises");
        let k = std::f64::consts::LN_2 / self.halflife_s;
        // Cold-start bias correction: after observing for W seconds the
        // expected decayed mass of a rate-λ Poisson stream is
        // (λ/k)·(1 − 2^(−W/halflife)), not λ/k — without the correction
        // the first few ticks' rate reads systematically low and the
        // closed loop under-provisions (and sees spurious rate drift).
        let window_s = self
            .start_t_s
            .map(|t0| (t_s - t0).max(0.0))
            .unwrap_or(0.0);
        let coverage = 1.0 - 0.5f64.powf(window_s / self.halflife_s);
        if coverage <= 0.0 {
            return self.prior.clone();
        }
        DemandSnapshot {
            rate_rps: self.total * k / coverage,
            mix,
        }
    }

    fn decay_to(&mut self, t_s: f64) {
        let dt = t_s - self.last_t_s;
        if dt > 0.0 {
            let f = 0.5f64.powf(dt / self.halflife_s);
            for c in self.counts.iter_mut() {
                *c *= f;
            }
            self.total *= f;
            self.last_t_s = t_s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{synthesize_trace, SynthOptions};

    fn snap(rate: f64, mix: TraceMix) -> DemandSnapshot {
        DemandSnapshot::new(rate, mix)
    }

    #[test]
    fn demand_drift_zero_on_identical_snapshots() {
        let a = snap(2.0, TraceMix::trace1());
        let b = snap(2.0, TraceMix::trace1());
        assert!(demand_drift(&a, &b).abs() < 1e-12);
        // Zero-rate edge: no NaN, still zero for identical.
        let z = snap(0.0, TraceMix::trace2());
        assert!(demand_drift(&z, &z).abs() < 1e-12);
    }

    #[test]
    fn demand_drift_scale_invariant_in_rate() {
        let a = snap(2.0, TraceMix::trace1());
        let b = snap(3.0, TraceMix::trace3());
        let d1 = demand_drift(&a, &b);
        let a10 = snap(20.0, TraceMix::trace1());
        let b10 = snap(30.0, TraceMix::trace3());
        let d10 = demand_drift(&a10, &b10);
        assert!((d1 - d10).abs() < 1e-12, "{d1} vs {d10}");
        assert!(d1 > 0.5, "trace1→trace3 shift should read as large: {d1}");
    }

    #[test]
    fn demand_drift_bounded_and_symmetric() {
        let a = snap(1.0, TraceMix::trace1());
        let b = snap(100.0, TraceMix::trace3());
        let d = demand_drift(&a, &b);
        assert!(d <= 2.0 + 1e-12, "d={d}");
        assert!((d - demand_drift(&b, &a)).abs() < 1e-12);
    }

    #[test]
    fn schedule_interpolates_and_clamps() {
        let s = MixSchedule::shift(
            "t1-to-t3",
            (TraceMix::trace1(), 2.0),
            (TraceMix::trace3(), 4.0),
            100.0,
            300.0,
        )
        .expect("valid shift");
        // Clamped outside the ramp.
        assert_eq!(s.mix_at(-50.0).ratios, TraceMix::trace1().ratios);
        assert_eq!(s.mix_at(0.0).ratios, TraceMix::trace1().ratios);
        assert_eq!(s.mix_at(1000.0).ratios, TraceMix::trace3().ratios);
        assert!((s.rate_at(0.0) - 2.0).abs() < 1e-12);
        assert!((s.rate_at(300.0) - 4.0).abs() < 1e-12);
        // Midpoint: mean ratios, mean rate, still a valid mixture.
        let mid = s.mix_at(200.0);
        let sum: f64 = mid.ratios.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "midpoint ratios sum {sum}");
        for (i, &r) in mid.ratios.iter().enumerate() {
            let want = 0.5 * (TraceMix::trace1().ratios[i] + TraceMix::trace3().ratios[i]);
            assert!((r - want).abs() < 1e-9, "type {i}: {r} vs {want}");
        }
        assert!((s.rate_at(200.0) - 3.0).abs() < 1e-12);
        assert!((s.max_rate() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn schedule_rejects_bad_keyframes() {
        assert!(MixSchedule::new("empty", Vec::new()).is_err());
        let out_of_order = vec![
            MixKeyframe {
                t_s: 10.0,
                mix: TraceMix::trace1(),
                rate_rps: 1.0,
            },
            MixKeyframe {
                t_s: 5.0,
                mix: TraceMix::trace2(),
                rate_rps: 1.0,
            },
        ];
        assert!(MixSchedule::new("backwards", out_of_order).is_err());
        assert!(MixSchedule::shift(
            "bad-ramp",
            (TraceMix::trace1(), 1.0),
            (TraceMix::trace2(), 1.0),
            200.0,
            100.0
        )
        .is_err());
    }

    #[test]
    fn estimator_converges_on_stationary_trace() {
        let mix = TraceMix::trace2();
        let rate = 20.0;
        let trace = synthesize_trace(
            &mix,
            &SynthOptions {
                num_requests: 20_000,
                arrival_rate: rate,
                length_sigma: 0.0,
                seed: 99,
            },
        );
        // A prior far from the truth, so convergence is the estimator's.
        let prior = DemandSnapshot::new(1.0, TraceMix::trace3());
        let mut est = MixEstimator::new(100.0, prior);
        let end = trace.requests.last().unwrap().arrival_s;
        est.observe_trace_window(&trace, 0.0, end + 1.0);
        let got = est.snapshot(end);
        let tv = got.mix.total_variation(&mix);
        assert!(tv < 0.05, "mixture TV {tv} after {} arrivals", trace.len());
        assert!(
            (got.rate_rps / rate - 1.0).abs() < 0.15,
            "rate estimate {} vs true {rate}",
            got.rate_rps
        );
    }

    #[test]
    fn estimator_rate_unbiased_from_cold_start() {
        // One half-life of observation: the decayed mass is only ~50% of
        // its steady state, so the naive total·k estimate would read ~half
        // the true rate; the coverage correction must repair it.
        let rate = 10.0;
        let trace = synthesize_trace(
            &TraceMix::trace1(),
            &SynthOptions {
                num_requests: 3_000,
                arrival_rate: rate,
                length_sigma: 0.0,
                seed: 5,
            },
        );
        let mut est = MixEstimator::new(300.0, DemandSnapshot::new(1.0, TraceMix::trace3()));
        est.observe_trace_window(&trace, 0.0, 300.0);
        let got = est.snapshot(300.0);
        assert!(
            (got.rate_rps / rate - 1.0).abs() < 0.15,
            "cold-start rate {} vs true {rate}",
            got.rate_rps
        );
    }

    #[test]
    fn estimator_cold_start_returns_prior() {
        let prior = DemandSnapshot::new(2.5, TraceMix::trace1());
        let mut est = MixEstimator::new(300.0, prior.clone());
        assert_eq!(est.snapshot(0.0), prior);
        // A couple of observations are still below the mass floor.
        est.observe(1.0, 0);
        est.observe(2.0, 4);
        assert_eq!(est.snapshot(3.0), prior);
    }

    #[test]
    fn estimator_tracks_a_shift() {
        // Saturate on trace1, then feed trace3 for many half-lives: the
        // estimate must move to the new mixture.
        let opts_a = SynthOptions {
            num_requests: 5_000,
            arrival_rate: 10.0,
            length_sigma: 0.0,
            seed: 7,
        };
        let a = synthesize_trace(&TraceMix::trace1(), &opts_a);
        let a_end = a.requests.last().unwrap().arrival_s;
        let b = synthesize_trace(&TraceMix::trace3(), &SynthOptions { seed: 8, ..opts_a });
        let mut est = MixEstimator::new(50.0, DemandSnapshot::new(10.0, TraceMix::trace1()));
        est.observe_trace_window(&a, 0.0, f64::INFINITY);
        for r in &b.requests {
            est.observe(a_end + r.arrival_s, r.workload.index);
        }
        let t_end = a_end + b.requests.last().unwrap().arrival_s;
        let got = est.snapshot(t_end);
        let to_new = got.mix.total_variation(&TraceMix::trace3());
        let to_old = got.mix.total_variation(&TraceMix::trace1());
        assert!(
            to_new < 0.1 && to_old > 0.3,
            "estimate did not track the shift: TV(new)={to_new} TV(old)={to_old}"
        );
    }

    #[test]
    fn demands_over_scales_with_epoch() {
        let s = snap(2.0, TraceMix::trace1());
        let d = s.demands_over(900.0);
        assert!((d.iter().sum::<f64>() - 1800.0).abs() < 1e-9);
        assert!((d[0] - 0.33 * 1800.0).abs() < 1e-9);
    }
}
