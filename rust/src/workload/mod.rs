//! Workload model: the paper's nine workload types (input length ∈
//! {2455, 824, 496} × output length ∈ {510, 253, 18}), the three evaluation
//! traces (Table 4 mixtures of those types), request records, a trace
//! synthesizer with Poisson arrivals and log-normal length jitter, the
//! demand-drift layer ([`drift`]): time-varying mix schedules, demand
//! snapshots, and the online mixture estimator — and the streaming arrival
//! generator ([`stream`]) that yields the same synthetic arrivals lazily in
//! O(1) memory for million-request simulations.

pub mod drift;
pub mod stream;
pub mod synth;

pub use drift::{demand_drift, DemandSnapshot, MixEstimator, MixKeyframe, MixSchedule};
pub use stream::ArrivalStream;
pub use synth::{synthesize_trace, synthesize_trace_schedule, SynthOptions};

use crate::util::json::Json;

/// Average input token lengths of the benchmark workload grid (§3).
pub const INPUT_LENGTHS: [u32; 3] = [2455, 824, 496];
/// Average output token lengths of the benchmark workload grid (§3).
pub const OUTPUT_LENGTHS: [u32; 3] = [510, 253, 18];

/// One of the nine benchmark workload types. `index` is 0..9 in the paper's
/// Figure 4 left-to-right order: (input, output) pairs iterate input-major:
/// (2455,510), (2455,253), (2455,18), (824,510), ..., (496,18).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct WorkloadType {
    pub index: usize,
    pub avg_input: u32,
    pub avg_output: u32,
}

impl WorkloadType {
    pub fn by_index(index: usize) -> WorkloadType {
        assert!(index < 9, "workload index {index} out of range");
        WorkloadType {
            index,
            avg_input: INPUT_LENGTHS[index / 3],
            avg_output: OUTPUT_LENGTHS[index % 3],
        }
    }

    pub fn all() -> Vec<WorkloadType> {
        (0..9).map(Self::by_index).collect()
    }

    pub fn label(&self) -> String {
        format!("{{{}, {}}}", self.avg_input, self.avg_output)
    }

    /// Paper's Figure 1 classification: input > 512 is "long input",
    /// output > 128 is "long output".
    pub fn class(&self) -> WorkloadClass {
        match (self.avg_input > 512, self.avg_output > 128) {
            (true, true) => WorkloadClass::LongInLongOut,
            (true, false) => WorkloadClass::LongInShortOut,
            (false, true) => WorkloadClass::ShortInLongOut,
            (false, false) => WorkloadClass::ShortInShortOut,
        }
    }

    /// Compute-intensity heuristic used in the paper's prose: long-input /
    /// short-output workloads are compute(prefill)-heavy; short-input /
    /// long-output are memory(decode)-heavy.
    pub fn compute_intensity(&self) -> f64 {
        self.avg_input as f64 / (self.avg_input as f64 + 4.0 * self.avg_output as f64)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WorkloadClass {
    LongInLongOut,
    LongInShortOut,
    ShortInLongOut,
    ShortInShortOut,
}

impl WorkloadClass {
    pub fn name(&self) -> &'static str {
        match self {
            WorkloadClass::LongInLongOut => "long-in/long-out",
            WorkloadClass::LongInShortOut => "long-in/short-out",
            WorkloadClass::ShortInLongOut => "short-in/long-out",
            WorkloadClass::ShortInShortOut => "short-in/short-out",
        }
    }
}

/// A named mixture over the nine workload types (Table 4).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceMix {
    pub name: String,
    /// Fractions over workload types 1..9; sums to 1.
    pub ratios: [f64; 9],
}

impl TraceMix {
    /// Trace 1 — subsampled from the Swiss AI Center production traces.
    pub fn trace1() -> TraceMix {
        TraceMix::new(
            "trace1-swiss-ai",
            [0.33, 0.07, 0.08, 0.07, 0.27, 0.06, 0.06, 0.03, 0.03],
        )
    }

    /// Trace 2 — subsampled from Azure-Trace (Splitwise production traces).
    pub fn trace2() -> TraceMix {
        TraceMix::new(
            "trace2-azure",
            [0.22, 0.05, 0.05, 0.21, 0.05, 0.05, 0.19, 0.06, 0.12],
        )
    }

    /// Trace 3 — subsampled from the WildGPT/WildChat dataset.
    pub fn trace3() -> TraceMix {
        TraceMix::new(
            "trace3-wildgpt",
            [0.04, 0.01, 0.04, 0.03, 0.20, 0.27, 0.01, 0.25, 0.15],
        )
    }

    pub fn by_name(name: &str) -> Option<TraceMix> {
        match name {
            "trace1" | "trace1-swiss-ai" | "swiss" => Some(Self::trace1()),
            "trace2" | "trace2-azure" | "azure" => Some(Self::trace2()),
            "trace3" | "trace3-wildgpt" | "wildgpt" | "wildchat" => Some(Self::trace3()),
            _ => None,
        }
    }

    pub fn all() -> Vec<TraceMix> {
        vec![Self::trace1(), Self::trace2(), Self::trace3()]
    }

    pub fn new(name: &str, ratios: [f64; 9]) -> TraceMix {
        let sum: f64 = ratios.iter().sum();
        assert!(
            (sum - 1.0).abs() < 1e-6,
            "trace mix '{name}' ratios sum to {sum}, expected 1"
        );
        assert!(ratios.iter().all(|&r| r >= 0.0));
        TraceMix {
            name: name.to_string(),
            ratios,
        }
    }

    /// Like [`TraceMix::new`], but renormalises instead of asserting the
    /// ratios sum to 1. The assert in `new()` is the right contract for the
    /// hand-written Table 4 mixtures, but wrong for drift-interpolated,
    /// estimator-derived, or CLI-supplied mixes subject to FP error — those
    /// call sites route through here. Errors on negative, non-finite, or
    /// all-zero ratios.
    pub fn normalized(name: &str, ratios: [f64; 9]) -> anyhow::Result<TraceMix> {
        if ratios.iter().any(|r| !r.is_finite() || *r < 0.0) {
            anyhow::bail!("trace mix '{name}': negative or non-finite ratio in {ratios:?}");
        }
        let sum: f64 = ratios.iter().sum();
        if sum <= 0.0 {
            anyhow::bail!("trace mix '{name}': ratios sum to {sum}, nothing to normalise");
        }
        let mut out = ratios;
        for r in out.iter_mut() {
            *r /= sum;
        }
        Ok(TraceMix {
            name: name.to_string(),
            ratios: out,
        })
    }

    /// Total-variation distance to another mixture: ½·Σ|aᵢ − bᵢ| ∈ [0, 1].
    /// The mixture half of the demand-drift metric.
    pub fn total_variation(&self, other: &TraceMix) -> f64 {
        let l1: f64 = self
            .ratios
            .iter()
            .zip(&other.ratios)
            .map(|(a, b)| (a - b).abs())
            .sum();
        0.5 * l1
    }

    /// Demand per workload type for a total of `total_requests` requests.
    pub fn demands(&self, total_requests: f64) -> [f64; 9] {
        let mut out = [0.0; 9];
        for (i, r) in self.ratios.iter().enumerate() {
            out[i] = r * total_requests;
        }
        out
    }

    /// The workload class fractions (Figure 1-style summary).
    pub fn class_fractions(&self) -> Vec<(WorkloadClass, f64)> {
        let mut acc: Vec<(WorkloadClass, f64)> = vec![
            (WorkloadClass::LongInLongOut, 0.0),
            (WorkloadClass::LongInShortOut, 0.0),
            (WorkloadClass::ShortInLongOut, 0.0),
            (WorkloadClass::ShortInShortOut, 0.0),
        ];
        for (i, &r) in self.ratios.iter().enumerate() {
            let class = WorkloadType::by_index(i).class();
            acc.iter_mut().find(|(c, _)| *c == class).unwrap().1 += r;
        }
        acc
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("ratios", Json::num_arr(&self.ratios)),
        ])
    }
}

/// A single request in a synthesized trace.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time in seconds from trace start.
    pub arrival_s: f64,
    pub workload: WorkloadType,
    /// Actual input token count (jittered around the type mean).
    pub input_tokens: u32,
    /// Actual output token count.
    pub output_tokens: u32,
}

/// A synthesized trace: requests sorted by arrival time.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub name: String,
    pub requests: Vec<Request>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.requests.len()
    }
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Count of requests per workload type index.
    pub fn counts_per_type(&self) -> [usize; 9] {
        let mut c = [0usize; 9];
        for r in &self.requests {
            c[r.workload.index] += 1;
        }
        c
    }

    /// Duration between first and last arrival.
    pub fn span_s(&self) -> f64 {
        if self.requests.is_empty() {
            0.0
        } else {
            self.requests.last().unwrap().arrival_s - self.requests[0].arrival_s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nine_types_grid() {
        let all = WorkloadType::all();
        assert_eq!(all.len(), 9);
        assert_eq!(all[0].avg_input, 2455);
        assert_eq!(all[0].avg_output, 510);
        assert_eq!(all[2].avg_input, 2455);
        assert_eq!(all[2].avg_output, 18);
        assert_eq!(all[8].avg_input, 496);
        assert_eq!(all[8].avg_output, 18);
    }

    #[test]
    fn classes_match_figure1_thresholds() {
        // {2455, 18}: long input, short output => compute-intensive.
        assert_eq!(
            WorkloadType::by_index(2).class(),
            WorkloadClass::LongInShortOut
        );
        // {496, 510}: short input, long output => memory-intensive.
        assert_eq!(
            WorkloadType::by_index(6).class(),
            WorkloadClass::ShortInLongOut
        );
    }

    #[test]
    fn compute_intensity_ordering() {
        // Long-input/short-output must rank above short-input/long-output.
        let compute_heavy = WorkloadType::by_index(2).compute_intensity(); // {2455,18}
        let memory_heavy = WorkloadType::by_index(6).compute_intensity(); // {496,510}
        assert!(compute_heavy > memory_heavy);
    }

    #[test]
    fn table4_mixtures_sum_to_one() {
        for t in TraceMix::all() {
            let s: f64 = t.ratios.iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "{}: {s}", t.name);
        }
    }

    #[test]
    fn table4_values_spot_check() {
        assert_eq!(TraceMix::trace1().ratios[0], 0.33);
        assert_eq!(TraceMix::trace2().ratios[3], 0.21);
        assert_eq!(TraceMix::trace3().ratios[5], 0.27);
    }

    #[test]
    fn trace3_is_memory_heavy() {
        // WildGPT (trace 3) is dominated by short-input types (the paper: the
        // A6000 homogeneous baseline wins there; our plan rents ~93%
        // workstation GPUs).
        let t3 = TraceMix::trace3();
        let short_in: f64 = t3.ratios[3..9].iter().sum();
        assert!(short_in > 0.85, "short-input fraction {short_in}");
    }

    #[test]
    fn demands_scale() {
        let d = TraceMix::trace1().demands(1000.0);
        assert!((d[0] - 330.0).abs() < 1e-9);
        assert!((d.iter().sum::<f64>() - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn by_name_aliases() {
        assert_eq!(TraceMix::by_name("trace1").unwrap().name, "trace1-swiss-ai");
        assert_eq!(TraceMix::by_name("azure").unwrap().name, "trace2-azure");
        assert!(TraceMix::by_name("nope").is_none());
    }

    #[test]
    fn normalized_renormalizes_instead_of_panicking() {
        // A drift-interpolated mix off by FP error: new() would assert,
        // normalized() repairs it.
        let mut ratios = TraceMix::trace1().ratios;
        ratios[0] += 1e-4;
        let m = TraceMix::normalized("fp-jitter", ratios).expect("renormalised");
        assert!((m.ratios.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        // Unnormalised counts (estimator-style) work too.
        let counts = [3.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let m = TraceMix::normalized("counts", counts).expect("counts normalise");
        assert!((m.ratios[0] - 0.75).abs() < 1e-12);
        assert!((m.ratios[1] - 0.25).abs() < 1e-12);
        // Degenerate inputs are errors, not panics.
        assert!(TraceMix::normalized("zero", [0.0; 9]).is_err());
        let mut neg = TraceMix::trace1().ratios;
        neg[3] = -0.1;
        assert!(TraceMix::normalized("neg", neg).is_err());
        let mut nan = TraceMix::trace1().ratios;
        nan[2] = f64::NAN;
        assert!(TraceMix::normalized("nan", nan).is_err());
    }

    #[test]
    fn total_variation_is_a_distance() {
        let a = TraceMix::trace1();
        let b = TraceMix::trace3();
        assert!(a.total_variation(&a).abs() < 1e-12);
        let d = a.total_variation(&b);
        assert!((d - b.total_variation(&a)).abs() < 1e-12);
        assert!(d > 0.0 && d <= 1.0, "tv={d}");
        // Known value for the paper mixtures: ½·Σ|Δ| = 0.55.
        assert!((d - 0.55).abs() < 1e-9, "tv={d}");
    }

    #[test]
    fn class_fractions_sum_to_one() {
        for t in TraceMix::all() {
            let s: f64 = t.class_fractions().iter().map(|(_, f)| f).sum();
            assert!((s - 1.0).abs() < 1e-9);
        }
    }
}
