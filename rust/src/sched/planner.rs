//! The unified planning surface: one contract for every way the codebase
//! deduces a serving plan.
//!
//! The paper's pipeline re-plans constantly — per bisection iterate, per
//! replan epoch, per baseline sweep — and before this module each of those
//! callers held its own free-function entry point with its own
//! `(Option<ServingPlan>, SearchStats)` tuple threading. The redesign makes
//! planning a *session* with persistent state, in line with ThunderServe's
//! lightweight online rescheduling and Mélange's composition-only fast
//! path:
//!
//! * [`PlanRequest`] — a builder-style request: the problem, an optional
//!   seed plan and warm makespan bound, the drift context the caller
//!   observed, and solver budget overrides (deadline / node caps);
//! * [`PlanReport`] — the uniform answer: the plan (or a structured
//!   [`Infeasibility`] reason), merged [`SearchStats`], and [`Provenance`]
//!   (strategy name plus fast-path/escalation/warm flags);
//! * [`Planner`] — the one trait every strategy implements: Algorithm 1
//!   ([`BisectionPlanner`]), the stateful [`PlannerSession`], and all the
//!   baselines in [`crate::baselines`];
//! * [`PlannerSession`] — the centerpiece: a planner that *owns* warm
//!   state. It carries the incumbent plan (seeding each exact MILP's first
//!   incumbent) and the terminal [`BasisSnapshot`] of the last feasibility
//!   root, which crash-warms the next root — across bisection iterates
//!   *and* across calls, so replan epochs no longer rebuild the arena per
//!   T̂ (see `milp/README.md`, "Basis snapshots").

use super::binary_search::{solve_binary_search_core, BasisCarry, BinarySearchOptions, SearchStats};
use super::{SchedProblem, ServingPlan};
use std::time::Duration;

/// The two-axis drift of the world signal since a plan's basis: `supply`
/// is market drift (availability + prices), `demand` is workload drift
/// (arrival rate + mixture). Callers attach it to a [`PlanRequest`] so a
/// planner can tell a price spike from a mixture shift; the orchestrator's
/// replan ladder thresholds the axes separately.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WorldDrift {
    pub supply: f64,
    pub demand: f64,
}

/// A planning request: what to plan and what the caller already knows.
/// Built with the `with_*` builder methods; only the problem is mandatory.
#[derive(Clone, Copy)]
pub struct PlanRequest<'a> {
    /// The problem to plan (budget, demands, availability, candidates).
    pub problem: &'a SchedProblem,
    /// A plan believed feasible — the incumbent when replanning. Seeds the
    /// exact feasibility MILPs' first incumbent.
    pub seed_plan: Option<&'a ServingPlan>,
    /// A makespan known (or believed) achievable; tightens the bisection's
    /// initial upper bound.
    pub warm_upper: Option<f64>,
    /// The drift the caller observed since the seed plan's world. The
    /// bisection planners ignore it; ladder planners (the orchestrator's
    /// `StrategyPlanner`) pick their rung — fast path, repair, escalation
    /// — from it.
    pub drift: Option<WorldDrift>,
    /// Wall-clock budget override for each feasibility MILP.
    pub deadline: Option<Duration>,
    /// Node-cap override for each feasibility MILP.
    pub max_nodes: Option<usize>,
}

impl<'a> PlanRequest<'a> {
    pub fn new(problem: &'a SchedProblem) -> Self {
        Self {
            problem,
            seed_plan: None,
            warm_upper: None,
            drift: None,
            deadline: None,
            max_nodes: None,
        }
    }

    /// Seed with an incumbent plan; its makespan becomes the warm upper
    /// bound unless one was set explicitly.
    pub fn with_seed(mut self, plan: &'a ServingPlan) -> Self {
        self.seed_plan = Some(plan);
        if self.warm_upper.is_none() {
            self.warm_upper = Some(plan.makespan);
        }
        self
    }

    pub fn with_warm_upper(mut self, makespan: f64) -> Self {
        self.warm_upper = Some(makespan);
        self
    }

    pub fn with_drift(mut self, drift: WorldDrift) -> Self {
        self.drift = Some(drift);
        self
    }

    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    pub fn with_max_nodes(mut self, max_nodes: usize) -> Self {
        self.max_nodes = Some(max_nodes);
        self
    }

    /// Search options with this request's solver-budget overrides applied.
    pub fn effective_opts(&self, base: &BinarySearchOptions) -> BinarySearchOptions {
        let mut opts = base.clone();
        if let Some(d) = self.deadline {
            opts.milp.time_limit = d;
        }
        if let Some(n) = self.max_nodes {
            opts.milp.max_nodes = n;
        }
        opts
    }
}

/// Why a planner returned no plan — structured, so callers can tell "this
/// workload can never be served" from "the search came up empty here".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Infeasibility {
    /// Some demanded (model, workload) pair has no candidate that can
    /// serve it at all (no finite makespan exists).
    Uncoverable,
    /// Candidates exist but no composition fits the budget and
    /// availability at any makespan the search probed.
    Exhausted,
    /// The planner's own restriction (a baseline's GPU-type or deployment
    /// filter) left no usable candidates for some model.
    NoCandidates,
}

impl std::fmt::Display for Infeasibility {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Infeasibility::Uncoverable => {
                write!(f, "a demanded workload has no candidate that can serve it")
            }
            Infeasibility::Exhausted => {
                write!(f, "no composition fits the budget and availability")
            }
            Infeasibility::NoCandidates => {
                write!(f, "the planner's restriction left no usable candidates")
            }
        }
    }
}

/// Where a report came from and which path produced it.
#[derive(Clone, Debug)]
pub struct Provenance {
    /// The producing strategy's name ([`Planner::name`]).
    pub strategy: String,
    /// The plan came from a composition-preserving fast path (assignment
    /// LP only, no replica moves). Set by ladder planners (the
    /// orchestrator's `StrategyPlanner`); the plain bisection planners
    /// have no fast path and always report `false`.
    pub fast_path: bool,
    /// The strategy escalated to a full re-solve to produce this plan
    /// (ladder planners only, like `fast_path`).
    pub escalated: bool,
    /// The solve started from carried warm state (a seed plan, a warm
    /// upper bound, or a session basis) rather than from scratch.
    pub warmed: bool,
    /// Some feasibility MILP blew its wall-clock deadline and answered
    /// with its best incumbent instead of a proven verdict (mirrors
    /// [`SearchStats::hit_deadline`]). The orchestrator's degradation
    /// ladder treats this as "the solver was late".
    pub hit_deadline: bool,
}

impl Provenance {
    pub fn cold(strategy: impl Into<String>) -> Self {
        Provenance {
            strategy: strategy.into(),
            fast_path: false,
            escalated: false,
            warmed: false,
            hit_deadline: false,
        }
    }
}

/// The uniform planning answer: exactly one of `plan` / `infeasible` is
/// set, alongside the merged solver statistics and the provenance.
#[derive(Clone, Debug)]
pub struct PlanReport {
    pub plan: Option<ServingPlan>,
    pub infeasible: Option<Infeasibility>,
    pub stats: SearchStats,
    pub provenance: Provenance,
}

impl PlanReport {
    /// A feasible report.
    pub fn found(plan: ServingPlan, stats: SearchStats, provenance: Provenance) -> Self {
        PlanReport {
            plan: Some(plan),
            infeasible: None,
            stats,
            provenance,
        }
    }

    /// An infeasible report with a structured reason.
    pub fn not_found(
        reason: Infeasibility,
        stats: SearchStats,
        provenance: Provenance,
    ) -> Self {
        PlanReport {
            plan: None,
            infeasible: Some(reason),
            stats,
            provenance,
        }
    }

    /// Consume the report, keeping only the plan (the pre-redesign shape).
    pub fn into_plan(self) -> Option<ServingPlan> {
        self.plan
    }
}

/// One planning strategy. Everything that deduces a serving plan — the
/// production bisection, the stateful session, every baseline — answers
/// the same `plan()` contract, so sweeps and comparisons iterate over
/// `Box<dyn Planner>` instead of divergent free functions.
pub trait Planner {
    /// Strategy name, used as the report's provenance and in CLI tables.
    fn name(&self) -> String;

    /// Produce a plan for the request. Must set exactly one of
    /// `PlanReport::plan` / `PlanReport::infeasible`.
    fn plan(&mut self, req: &PlanRequest) -> PlanReport;
}

/// Classify why a bisection came up empty on `p`.
fn bisection_infeasibility(p: &SchedProblem) -> Infeasibility {
    if p.makespan_upper_bound().is_none() {
        Infeasibility::Uncoverable
    } else {
        Infeasibility::Exhausted
    }
}

/// Algorithm 1 (binary-search-on-T) as a stateless [`Planner`]: each call
/// plans from scratch, using only the warm hints the request itself
/// carries. Use [`PlannerSession`] when consecutive calls should feed each
/// other.
#[derive(Clone, Debug)]
pub struct BisectionPlanner {
    pub opts: BinarySearchOptions,
}

impl BisectionPlanner {
    pub fn new(opts: BinarySearchOptions) -> Self {
        Self { opts }
    }
}

impl Planner for BisectionPlanner {
    fn name(&self) -> String {
        "bisection".to_string()
    }

    fn plan(&mut self, req: &PlanRequest) -> PlanReport {
        let opts = req.effective_opts(&self.opts);
        let mut basis = BasisCarry::default();
        let (plan, stats) = solve_binary_search_core(
            req.problem,
            &opts,
            req.warm_upper,
            req.seed_plan,
            &mut basis,
        );
        let mut provenance = Provenance::cold(self.name());
        provenance.warmed = req.seed_plan.is_some() || req.warm_upper.is_some();
        provenance.hit_deadline = stats.hit_deadline;
        match plan {
            Some(plan) => PlanReport::found(plan, stats, provenance),
            None => {
                PlanReport::not_found(bisection_infeasibility(req.problem), stats, provenance)
            }
        }
    }
}

/// The stateful planner: Algorithm 1 plus persistent warm state across
/// calls. The session owns
///
/// * the **incumbent plan** of its last successful solve — used as the
///   seed (first MILP incumbent + warm makespan bound) whenever the
///   request doesn't bring its own; and
/// * the **root bases** ([`BasisCarry`]) of the last feasibility checks —
///   one snapshot per oracle (exact MILP root, knapsack rounding root) —
///   crash-warming the first root of the next call, so consecutive
///   bisections (replan epochs, baseline sweeps over the same problem
///   family) skip the two-phase cold start entirely.
///
/// Both carries are self-guarding: a seed that doesn't map onto the
/// request's candidate space is dropped, and a basis whose dimensions
/// don't match the new feasibility model is refused by the arena itself.
#[derive(Debug, Default)]
pub struct PlannerSession {
    opts: BinarySearchOptions,
    incumbent: Option<ServingPlan>,
    basis: BasisCarry,
    /// Calls served so far (diagnostics).
    solves: usize,
}

impl PlannerSession {
    pub fn new(opts: BinarySearchOptions) -> Self {
        Self {
            opts,
            incumbent: None,
            basis: BasisCarry::default(),
            solves: 0,
        }
    }

    /// The search options this session plans with.
    pub fn opts(&self) -> &BinarySearchOptions {
        &self.opts
    }

    /// The incumbent plan of the last successful solve, if any.
    pub fn incumbent(&self) -> Option<&ServingPlan> {
        self.incumbent.as_ref()
    }

    /// True when the next call will crash-warm its root from a carried
    /// basis.
    pub fn has_warm_basis(&self) -> bool {
        self.basis.is_warm() && self.opts.carry_basis
    }

    /// Calls served so far.
    pub fn solves(&self) -> usize {
        self.solves
    }

    /// Drop all carried warm state (incumbent and bases) — e.g. when the
    /// caller switches to an unrelated problem family.
    pub fn reset(&mut self) {
        self.incumbent = None;
        self.basis.clear();
    }

    /// Adopt an externally produced plan (a fast-path or incremental
    /// repair that did not run through the session) as the incumbent, so
    /// the session's seed tracks the plan actually in force. The carried
    /// basis is untouched — it belongs to the last full solve, which is
    /// exactly the right crash start for the next escalation.
    pub fn observe_incumbent(&mut self, plan: &ServingPlan) {
        self.incumbent = Some(plan.clone());
    }

    /// A seed plan is only usable when it indexes into this problem's
    /// candidate space (sessions survive problem swaps; stale seeds must
    /// not).
    fn seed_applies(plan: &ServingPlan, p: &SchedProblem) -> bool {
        plan.entries.iter().all(|e| e.candidate < p.candidates.len())
    }
}

impl Planner for PlannerSession {
    fn name(&self) -> String {
        "session".to_string()
    }

    fn plan(&mut self, req: &PlanRequest) -> PlanReport {
        let opts = req.effective_opts(&self.opts);
        let own_seed = self
            .incumbent
            .as_ref()
            .filter(|plan| Self::seed_applies(plan, req.problem));
        let seed = req
            .seed_plan
            .filter(|plan| Self::seed_applies(plan, req.problem))
            .or(own_seed);
        let warm_upper = req.warm_upper.or_else(|| seed.map(|plan| plan.makespan));
        let warmed = seed.is_some() || warm_upper.is_some() || self.has_warm_basis();
        if !opts.carry_basis {
            self.basis.clear();
        }
        let (plan, stats) =
            solve_binary_search_core(req.problem, &opts, warm_upper, seed, &mut self.basis);
        self.solves += 1;
        let mut provenance = Provenance::cold(self.name());
        provenance.warmed = warmed;
        provenance.hit_deadline = stats.hit_deadline;
        match plan {
            Some(plan) => {
                self.incumbent = Some(plan.clone());
                PlanReport::found(plan, stats, provenance)
            }
            None => {
                PlanReport::not_found(bisection_infeasibility(req.problem), stats, provenance)
            }
        }
    }
}

/// One-shot convenience: plan `p` with Algorithm 1 under `opts` through
/// the [`Planner`] contract (benches and examples use this where no state
/// needs to persist).
pub fn plan_once(p: &SchedProblem, opts: &BinarySearchOptions) -> PlanReport {
    BisectionPlanner::new(opts.clone()).plan(&PlanRequest::new(p))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::binary_search::Feasibility;
    use crate::sched::toy::simple_example;

    fn exact_opts() -> BinarySearchOptions {
        BinarySearchOptions {
            tolerance: 0.05,
            feasibility: Feasibility::Exact,
            ..Default::default()
        }
    }

    #[test]
    fn bisection_planner_reports_plan_and_stats() {
        let p = simple_example();
        let report = plan_once(&p, &exact_opts());
        let plan = report.plan.as_ref().expect("toy plan");
        plan.validate(&p, 1e-4).unwrap();
        assert!(report.infeasible.is_none());
        assert_eq!(report.provenance.strategy, "bisection");
        assert!(!report.provenance.warmed);
        assert!(report.stats.pivots > 0);
        assert_eq!(report.stats.iterates.len(), report.stats.feasibility_checks);
    }

    #[test]
    fn infeasibility_reasons_are_structured() {
        // Zero availability: candidates exist but nothing fits.
        let mut starved = simple_example();
        starved.avail = vec![0, 0, 0];
        let r = plan_once(&starved, &exact_opts());
        assert_eq!(r.infeasible, Some(Infeasibility::Exhausted), "{:?}", r.plan);
        // No candidate at all for the demanded workloads.
        let mut uncoverable = simple_example();
        uncoverable.candidates.clear();
        let r = plan_once(&uncoverable, &exact_opts());
        assert_eq!(r.infeasible, Some(Infeasibility::Uncoverable));
        assert!(format!("{}", r.infeasible.unwrap()).contains("no candidate"));
    }

    #[test]
    fn session_carries_incumbent_and_basis_across_calls() {
        let p = simple_example();
        let mut session = PlannerSession::new(exact_opts());
        assert!(!session.has_warm_basis());
        let first = session.plan(&PlanRequest::new(&p));
        let first_plan = first.plan.expect("first plan");
        assert!(!first.provenance.warmed, "first call has nothing to warm");
        assert!(session.has_warm_basis(), "terminal basis not captured");
        assert!(session.incumbent().is_some());

        let second = session.plan(&PlanRequest::new(&p));
        let second_plan = second.plan.expect("second plan");
        assert!(second.provenance.warmed);
        assert!(
            second.stats.basis_roots > 0,
            "second call never crash-warmed a root from the carried basis"
        );
        assert!(
            (second_plan.makespan - first_plan.makespan).abs() <= 0.2,
            "session drifted: {} vs {}",
            second_plan.makespan,
            first_plan.makespan
        );
        assert_eq!(session.solves(), 2);
    }

    #[test]
    fn session_cost_matches_cold_planner_to_tolerance() {
        let p = simple_example();
        let cold = plan_once(&p, &exact_opts()).plan.expect("cold plan");
        let mut session = PlannerSession::new(exact_opts());
        session.plan(&PlanRequest::new(&p));
        let warm = session
            .plan(&PlanRequest::new(&p))
            .plan
            .expect("warm plan");
        assert!(
            (warm.makespan - cold.makespan).abs() <= 0.2,
            "warm {} vs cold {}",
            warm.makespan,
            cold.makespan
        );
        // Both stay within the same budget, so cost can only differ by
        // which equal-makespan optimum was picked.
        assert!(warm.cost(&p) <= p.budget + 1e-6);
    }

    #[test]
    fn session_drops_stale_seed_on_problem_swap() {
        let p = simple_example();
        let mut session = PlannerSession::new(exact_opts());
        session.plan(&PlanRequest::new(&p));
        // A problem with fewer candidates: the stored incumbent indexes
        // out of range and must be dropped, not crash the solve.
        let mut smaller = simple_example();
        smaller.candidates.truncate(2);
        let report = session.plan(&PlanRequest::new(&smaller));
        if let Some(plan) = &report.plan {
            plan.validate(&smaller, 1e-4).unwrap();
        }
        session.reset();
        assert!(session.incumbent().is_none() && !session.has_warm_basis());
    }

    #[test]
    fn request_builder_applies_overrides() {
        let p = simple_example();
        let plan = plan_once(&p, &exact_opts()).plan.unwrap();
        let req = PlanRequest::new(&p)
            .with_seed(&plan)
            .with_drift(WorldDrift {
                supply: 0.1,
                demand: 0.0,
            })
            .with_deadline(Duration::from_secs(3))
            .with_max_nodes(500);
        assert_eq!(req.warm_upper, Some(plan.makespan));
        let eff = req.effective_opts(&exact_opts());
        assert_eq!(eff.milp.max_nodes, 500);
        assert_eq!(eff.milp.time_limit, Duration::from_secs(3));
        let report = BisectionPlanner::new(exact_opts()).plan(&req);
        assert!(report.provenance.warmed);
        let got = report.plan.expect("seeded plan");
        assert!((got.makespan - plan.makespan).abs() <= 0.2);
    }
}
