//! Configuration enumeration (§4.3 "we enumerate all feasible integer
//! combinations {d_n(c)} in a precomputation step", constrained by the
//! Appendix D heuristics):
//!
//! * **memory check** — Σ d_n(c)·m_n must cover the model's weight floor
//!   (and our tighter per-stage placement check via the perf model);
//! * **connectivity** — TP only within a single machine (max GPUs/node);
//! * **TP degrees** — powers of two up to the node size;
//! * **PP stages** — homogeneous-type pipelines of 1..=4 stages, plus
//!   two-type mixed pipelines (the HexGen-style asymmetric case);
//! * **domination pruning** (Appendix G) — per model, configs whose
//!   throughput on *every* workload type is beaten by a strictly cheaper
//!   config are dropped.

use crate::catalog::{GpuSpec, GpuType};
use crate::perf_model::{ModelSpec, PerfModel, ReplicaConfig, StageConfig};
use crate::workload::WorkloadType;

/// Enumeration options.
#[derive(Clone, Debug)]
pub struct EnumOptions {
    /// Max pipeline stages to consider.
    pub max_pp: usize,
    /// Include heterogeneous (two-GPU-type) pipelines.
    pub mixed_pipelines: bool,
    /// Cap on GPUs per replica.
    pub max_gpus_per_replica: usize,
    /// Apply the Appendix G domination pruning.
    pub prune_dominated: bool,
}

impl Default for EnumOptions {
    fn default() -> Self {
        Self {
            max_pp: 4,
            mixed_pipelines: true,
            max_gpus_per_replica: 8,
            prune_dominated: true,
        }
    }
}

/// Enumerate feasible replica configurations for `model`.
///
/// Feasibility = the perf model can place the weights and at least one
/// request (the Appendix D memory check, tightened), TP fits in one node
/// (connectivity constraint), and the GPU budget per replica is respected.
pub fn enumerate_configs(
    model: &ModelSpec,
    perf: &PerfModel,
    opts: &EnumOptions,
) -> Vec<ReplicaConfig> {
    let mut out: Vec<ReplicaConfig> = Vec::new();

    // Homogeneous configurations: tp ∈ {1,2,4,8} × pp ∈ {1..max_pp}.
    for &gpu in &GpuType::ALL {
        let node = GpuSpec::of(gpu).max_gpus_per_node;
        for tp in [1usize, 2, 4, 8] {
            if tp > node {
                continue; // connectivity: TP within a single machine
            }
            for pp in 1..=opts.max_pp {
                let total = tp * pp;
                if total > opts.max_gpus_per_replica {
                    continue;
                }
                let cfg = ReplicaConfig::uniform(gpu, tp, pp);
                if perf.fits(&cfg, model) {
                    out.push(cfg);
                }
            }
        }
    }

    // Mixed two-type pipelines (asymmetric partitioning à la HexGen): two
    // stages, each a TP group of a single type. Only pairs where both
    // stages satisfy the connectivity constraint.
    if opts.mixed_pipelines {
        for &g1 in &GpuType::ALL {
            for &g2 in &GpuType::ALL {
                if g1 >= g2 {
                    continue; // unordered pair, distinct types
                }
                for tp1 in [1usize, 2, 4] {
                    for tp2 in [1usize, 2, 4] {
                        if tp1 > GpuSpec::of(g1).max_gpus_per_node
                            || tp2 > GpuSpec::of(g2).max_gpus_per_node
                            || tp1 + tp2 > opts.max_gpus_per_replica
                        {
                            continue;
                        }
                        let cfg = ReplicaConfig {
                            stages: vec![
                                StageConfig { gpu: g1, tp: tp1 },
                                StageConfig { gpu: g2, tp: tp2 },
                            ],
                        };
                        if perf.fits(&cfg, model) {
                            out.push(cfg);
                        }
                    }
                }
            }
        }
    }

    if opts.prune_dominated {
        out = prune_dominated(out, model, perf);
    }
    out
}

/// Appendix G pruning: drop configs strictly dominated on every workload
/// type by a config of equal or lower price.
fn prune_dominated(
    configs: Vec<ReplicaConfig>,
    model: &ModelSpec,
    perf: &PerfModel,
) -> Vec<ReplicaConfig> {
    let workloads = WorkloadType::all();
    // Precompute throughput vectors.
    let profiles: Vec<(f64, Vec<f64>)> = configs
        .iter()
        .map(|c| {
            let thr: Vec<f64> = workloads
                .iter()
                .map(|w| {
                    perf.estimate(c, model, w)
                        .map(|e| e.throughput_rps)
                        .unwrap_or(0.0)
                })
                .collect();
            (c.cost_per_hour(), thr)
        })
        .collect();
    let mut keep = vec![true; configs.len()];
    for i in 0..configs.len() {
        if !keep[i] {
            continue;
        }
        for j in 0..configs.len() {
            if i == j || !keep[i] {
                break;
            }
            if !keep[j] {
                continue;
            }
            // j dominates i if cost_j <= cost_i and thr_j >= thr_i on all
            // workloads, strictly better somewhere (or strictly cheaper).
            let (ci, ti) = &profiles[i];
            let (cj, tj) = &profiles[j];
            let cheaper_eq = cj <= ci;
            let all_geq = tj.iter().zip(ti).all(|(a, b)| a >= b);
            let strictly = cj < ci || tj.iter().zip(ti).any(|(a, b)| a > b);
            if cheaper_eq && all_geq && strictly {
                keep[i] = false;
            }
        }
    }
    configs
        .into_iter()
        .zip(keep)
        .filter_map(|(c, k)| if k { Some(c) } else { None })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (PerfModel, EnumOptions) {
        (PerfModel::default(), EnumOptions::default())
    }

    #[test]
    fn enumerates_something_for_both_models() {
        let (p, o) = setup();
        let c70 = enumerate_configs(&ModelSpec::llama3_70b(), &p, &o);
        let c8 = enumerate_configs(&ModelSpec::llama3_8b(), &p, &o);
        assert!(!c70.is_empty());
        assert!(!c8.is_empty());
        // 8B fits single GPUs; 70B does not.
        assert!(c8.iter().any(|c| c.total_gpus() == 1));
        assert!(c70.iter().all(|c| c.total_gpus() >= 2));
    }

    #[test]
    fn all_configs_fit_memory() {
        let (p, o) = setup();
        let m = ModelSpec::llama3_70b();
        for c in enumerate_configs(&m, &p, &o) {
            assert!(p.fits(&c, &m), "config {} does not fit", c.label());
        }
    }

    #[test]
    fn connectivity_constraint_respected() {
        let (p, o) = setup();
        for m in [ModelSpec::llama3_8b(), ModelSpec::llama3_70b()] {
            for c in enumerate_configs(&m, &p, &o) {
                for s in &c.stages {
                    assert!(
                        s.tp <= GpuSpec::of(s.gpu).max_gpus_per_node,
                        "TP {} exceeds node size for {}",
                        s.tp,
                        s.gpu.name()
                    );
                }
            }
        }
    }

    #[test]
    fn replica_gpu_cap_respected() {
        let (p, _) = setup();
        let o = EnumOptions {
            max_gpus_per_replica: 4,
            ..Default::default()
        };
        for c in enumerate_configs(&ModelSpec::llama3_70b(), &p, &o) {
            assert!(c.total_gpus() <= 4, "{}", c.label());
        }
    }

    #[test]
    fn pruning_reduces_count_and_preserves_best() {
        let (p, _) = setup();
        let m = ModelSpec::llama3_70b();
        let unpruned = enumerate_configs(
            &m,
            &p,
            &EnumOptions {
                prune_dominated: false,
                ..Default::default()
            },
        );
        let pruned = enumerate_configs(&m, &p, &EnumOptions::default());
        assert!(pruned.len() < unpruned.len());
        // Best throughput/$ per workload must survive pruning.
        for w in WorkloadType::all() {
            let best = |set: &[ReplicaConfig]| {
                set.iter()
                    .filter_map(|c| p.throughput_per_dollar(c, &m, &w))
                    .fold(0.0, f64::max)
            };
            let b_un = best(&unpruned);
            let b_pr = best(&pruned);
            assert!(
                b_pr >= b_un * 0.999,
                "w{}: pruned best {b_pr} < unpruned {b_un}",
                w.index
            );
        }
    }

    #[test]
    fn mixed_pipelines_toggle() {
        let (p, _) = setup();
        let m = ModelSpec::llama3_70b();
        let no_mixed = enumerate_configs(
            &m,
            &p,
            &EnumOptions {
                mixed_pipelines: false,
                prune_dominated: false,
                ..Default::default()
            },
        );
        assert!(no_mixed.iter().all(|c| c.is_homogeneous()));
        let mixed = enumerate_configs(
            &m,
            &p,
            &EnumOptions {
                mixed_pipelines: true,
                prune_dominated: false,
                ..Default::default()
            },
        );
        assert!(mixed.iter().any(|c| !c.is_homogeneous()));
    }
}
