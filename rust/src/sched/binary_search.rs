//! Algorithm 1: **binary-search-on-T** (Appendix F).
//!
//! Instead of minimising T directly, we bisect on a candidate makespan T̂ and
//! ask whether a feasible serving plan exists that finishes within T̂. With
//! T̂ fixed the makespan constraint becomes *linear*:
//!
//!   Σ_w x_{c,w}·λ_w/h_{c,w} ≤ T̂·y_c
//!
//! so each feasibility check is a compact MILP (integer y_c ≥ 0, no copy
//! expansion, no big-M). Two feasibility oracles are provided:
//!
//! * **exact** — minimise rental cost via branch & bound; feasible iff the
//!   optimum is within budget;
//! * **knapsack-approximate** (the paper's accelerator) — solve the LP
//!   relaxation, then round activations up and greedily repair against the
//!   budget/availability knapsack; conservative (may declare a feasible T̂
//!   infeasible by a small margin) but much faster.

// Determinism-zone lint policy (mirrors pallas-lint rule P001): no
// unwrap() outside tests - use expect("invariant") or propagate.
#![cfg_attr(not(test), warn(clippy::unwrap_used))]

use super::{PlanEntry, SchedProblem, ServingPlan};
use crate::milp::knapsack::{round_integral, RoundingStats};
use crate::milp::{
    solve_counted, solve_milp_session, BasisSnapshot, Cmp, Lp, LpResult, MilpOptions, MilpResult,
    MilpStats,
};
use crate::telemetry;
use std::time::{Duration, Instant};

/// The warm bases a bisection carries across T̂ iterates — and, via
/// [`crate::sched::planner::PlannerSession`], across whole solves. The two
/// feasibility oracles solve structurally different models (the knapsack
/// mode adds a budget row), so each carries its own snapshot; a snapshot is
/// only ever offered back to the oracle that produced it, and the arenas
/// refuse dimension mismatches on top.
#[derive(Clone, Debug, Default)]
pub struct BasisCarry {
    /// Terminal root basis of the last exact feasibility MILP.
    pub exact: Option<BasisSnapshot>,
    /// Root basis of the last knapsack rounding LP.
    pub knapsack: Option<BasisSnapshot>,
}

impl BasisCarry {
    /// Any basis on board?
    pub fn is_warm(&self) -> bool {
        self.exact.is_some() || self.knapsack.is_some()
    }

    /// Drop both carried bases.
    pub fn clear(&mut self) {
        self.exact = None;
        self.knapsack = None;
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Feasibility {
    /// Exact branch-and-bound cost minimisation.
    Exact,
    /// LP relaxation + knapsack rounding (Appendix F acceleration).
    Knapsack,
}

#[derive(Clone, Debug)]
pub struct BinarySearchOptions {
    /// Bisection tolerance τ (seconds).
    pub tolerance: f64,
    pub feasibility: Feasibility,
    /// Budget for each exact feasibility MILP.
    pub milp: MilpOptions,
    /// Hard cap on bisection iterations.
    pub max_iters: usize,
    /// Carry the terminal root basis of each exact feasibility MILP into
    /// the next one (crash-warming the root instead of a two-phase cold
    /// start) — across T̂ iterates within a run, and across runs when the
    /// caller is a [`crate::sched::planner::PlannerSession`]. `false`
    /// rebuilds the arena cold per T̂ (the pre-session behaviour, kept as
    /// the `fig_solver` baseline).
    pub carry_basis: bool,
}

impl Default for BinarySearchOptions {
    fn default() -> Self {
        Self {
            tolerance: 1.0,
            feasibility: Feasibility::Knapsack,
            milp: MilpOptions {
                time_limit: Duration::from_secs(10),
                max_nodes: 20_000,
                ..Default::default()
            },
            max_iters: 64,
            carry_basis: true,
        }
    }
}

/// Per-feasibility-check statistics of one bisection run — the `fig_solver`
/// bench reports the warm-hit profile *per iterate* from these.
#[derive(Clone, Copy, Debug)]
pub struct IterateStat {
    /// The makespan guess T̂ this feasibility check probed.
    pub t_hat: f64,
    /// Whether a feasible plan existed within T̂.
    pub feasible: bool,
    /// Simplex pivots this check cost.
    pub pivots: u64,
    /// MILP node LPs served warm (dual simplex) during this check.
    pub warm_solves: usize,
    /// MILP node LPs solved cold during this check.
    pub cold_solves: usize,
    /// True when this check's root LP was crash-warmed from a basis
    /// carried in from a previous iterate (or a previous session solve).
    pub from_basis: bool,
}

impl IterateStat {
    /// Fraction of this check's LP solves served by a warm path.
    pub fn warm_hit_rate(&self) -> f64 {
        let total = self.warm_solves + self.cold_solves;
        if total == 0 {
            0.0
        } else {
            self.warm_solves as f64 / total as f64
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct SearchStats {
    pub iterations: usize,
    pub feasibility_checks: usize,
    pub lp_solves: usize,
    /// Simplex pivots across every LP the search touched (assignment LPs,
    /// knapsack roundings, and the exact-mode MILP nodes alike).
    pub pivots: u64,
    /// Branch-and-bound nodes explored by the exact feasibility MILPs.
    pub milp_nodes: usize,
    /// MILP node LPs re-solved warm (dual simplex from the parent basis).
    pub warm_solves: usize,
    /// MILP node LPs solved cold (two-phase primal from scratch).
    pub cold_solves: usize,
    /// Feasibility checks whose root LP was crash-warmed from the basis
    /// carried across T̂ iterates / session solves — exact MILP roots and
    /// knapsack rounding roots alike.
    pub basis_roots: usize,
    /// Basis refactorisations (LU rebuilds) across every arena the search
    /// touched.
    pub refactorisations: u64,
    /// Product-form eta columns appended (factorized arenas only).
    pub eta_updates: u64,
    /// Pivots priced by dual steepest-edge (factorized arenas only).
    pub dse_pivots: u64,
    /// One entry per feasibility check, in probe order.
    pub iterates: Vec<IterateStat>,
    /// Some exact feasibility MILP hit its wall-clock deadline and returned
    /// its best incumbent rather than a proven verdict. Sticky across
    /// iterates and merges — the orchestrator's degradation trigger.
    pub hit_deadline: bool,
    pub elapsed: Duration,
}

impl SearchStats {
    /// Fold one exact feasibility MILP's statistics into the search totals.
    fn absorb_milp(&mut self, m: &MilpStats) {
        self.lp_solves += m.lp_solves;
        self.pivots += m.pivots;
        self.milp_nodes += m.nodes;
        self.warm_solves += m.warm_solves;
        self.cold_solves += m.cold_solves;
        self.basis_roots += m.basis_roots;
        self.refactorisations += m.refactorisations;
        self.eta_updates += m.eta_updates;
        self.dse_pivots += m.dse_pivots;
        self.hit_deadline |= m.hit_deadline;
    }

    /// Fold one knapsack rounding run's counters into the search totals.
    fn absorb_rounding(&mut self, r: &RoundingStats) {
        self.lp_solves += r.lp_solves;
        self.pivots += r.pivots;
        self.warm_solves += r.warm_solves;
        self.cold_solves += r.cold_solves;
        self.basis_roots += r.from_basis as usize;
        self.refactorisations += r.refactorisations;
        self.eta_updates += r.eta_updates;
        self.dse_pivots += r.dse_pivots;
    }

    /// Accumulate another search's statistics (replanning ladders and the
    /// orchestrator's per-horizon totals fold through here).
    pub fn merge(&mut self, other: &SearchStats) {
        self.iterations += other.iterations;
        self.feasibility_checks += other.feasibility_checks;
        self.lp_solves += other.lp_solves;
        self.pivots += other.pivots;
        self.milp_nodes += other.milp_nodes;
        self.warm_solves += other.warm_solves;
        self.cold_solves += other.cold_solves;
        self.basis_roots += other.basis_roots;
        self.refactorisations += other.refactorisations;
        self.eta_updates += other.eta_updates;
        self.dse_pivots += other.dse_pivots;
        self.iterates.extend_from_slice(&other.iterates);
        self.hit_deadline |= other.hit_deadline;
        self.elapsed += other.elapsed;
    }

    /// Fraction of MILP node LPs served by the warm (dual-simplex) path.
    pub fn warm_hit_rate(&self) -> f64 {
        let total = self.warm_solves + self.cold_solves;
        if total == 0 {
            0.0
        } else {
            self.warm_solves as f64 / total as f64
        }
    }
}

/// The feasibility LP/MILP at a fixed T̂.
struct FeasModel {
    lp: Lp,
    y_base: usize,
    x_index: Vec<Vec<usize>>, // per candidate per workload; MAX = absent
}

fn build_feasibility(p: &SchedProblem, t_hat: f64) -> Option<FeasModel> {
    // Variable layout: [x vars][y vars].
    let mut x_index: Vec<Vec<usize>> = Vec::with_capacity(p.candidates.len());
    let mut next = 0usize;
    for c in &p.candidates {
        let row: Vec<usize> = c
            .h
            .iter()
            .enumerate()
            .map(|(w, &h)| {
                if h > 0.0 && p.demands[c.model][w] > 0.0 {
                    let v = next;
                    next += 1;
                    v
                } else {
                    usize::MAX
                }
            })
            .collect();
        x_index.push(row);
    }
    let y_base = next;
    let num_vars = y_base + p.candidates.len();
    let mut lp = Lp::new(num_vars);

    // Workload fractions are shares: x ∈ [0, 1] natively.
    for v in 0..y_base {
        lp.set_bounds(v, 0.0, 1.0);
    }

    // Objective: minimise rental cost. Native per-candidate caps from the
    // budget and the per-type pools give every y a finite range, which the
    // warm-started B&B exploits (finite ranges flip instead of pivoting,
    // and reverted branches never pass through an infinite bound).
    for (ci, c) in p.candidates.iter().enumerate() {
        lp.set_objective(y_base + ci, c.cost);
        let by_budget = if c.cost > 0.0 {
            (p.budget / c.cost).floor()
        } else {
            f64::INFINITY
        };
        let by_avail = c
            .gpu_counts
            .iter()
            .enumerate()
            .filter(|&(_, &d)| d > 0)
            .map(|(n, &d)| (p.avail[n] / d) as f64)
            .fold(f64::INFINITY, f64::min);
        let cap = by_budget.min(by_avail);
        if cap.is_finite() {
            lp.set_bounds(y_base + ci, 0.0, cap);
        }
    }

    // Assignment rows.
    for (m, dm) in p.demands.iter().enumerate() {
        for (w, &lambda) in dm.iter().enumerate() {
            if lambda <= 0.0 {
                continue;
            }
            let terms: Vec<(usize, f64)> = p
                .candidates
                .iter()
                .enumerate()
                .filter(|(_, c)| c.model == m)
                .filter_map(|(ci, _)| {
                    let v = x_index[ci][w];
                    (v != usize::MAX).then_some((v, 1.0))
                })
                .collect();
            if terms.is_empty() {
                return None;
            }
            lp.add(terms, Cmp::Eq, 1.0);
        }
    }

    // Makespan rows (linear at fixed T̂): Σ_w x·λ/h − T̂·y ≤ 0.
    for (ci, c) in p.candidates.iter().enumerate() {
        let mut terms: Vec<(usize, f64)> = Vec::new();
        for (w, &h) in c.h.iter().enumerate() {
            let v = x_index[ci][w];
            if v == usize::MAX {
                continue;
            }
            terms.push((v, p.demands[c.model][w] / h));
        }
        if terms.is_empty() {
            continue;
        }
        terms.push((y_base + ci, -t_hat));
        lp.add(terms, Cmp::Le, 0.0);
    }

    // Availability rows.
    for n in 0..p.num_gpu_types {
        let terms: Vec<(usize, f64)> = p
            .candidates
            .iter()
            .enumerate()
            .filter(|(_, c)| c.gpu_counts[n] > 0)
            .map(|(ci, c)| (y_base + ci, c.gpu_counts[n] as f64))
            .collect();
        if !terms.is_empty() {
            lp.add(terms, Cmp::Le, p.avail[n] as f64);
        }
    }

    Some(FeasModel {
        lp,
        y_base,
        x_index,
    })
}

/// Map a serving plan onto the feasibility model's variable layout — the
/// seed the exact MILP starts from. The layout depends only on the problem
/// (not on T̂), so one vector carries across every bisection iteration.
fn plan_solution(model: &FeasModel, plan: &ServingPlan) -> Vec<f64> {
    let mut x = vec![0.0; model.lp.num_vars];
    for e in &plan.entries {
        x[model.y_base + e.candidate] = e.replicas as f64;
        for (w, &v) in model.x_index[e.candidate].iter().enumerate() {
            if v != usize::MAX {
                if let Some(&f) = e.fractions.get(w) {
                    x[v] = f;
                }
            }
        }
    }
    x
}

/// Outcome of one feasibility check: a concrete plan if feasible, plus an
/// [`IterateStat`] appended to `stats.iterates`. `carry` holds the previous
/// feasible MILP solution (same layout for every T̂); it seeds the exact
/// solver's incumbent and is replaced on success. `basis` carries the root
/// bases of the previous checks: with `opts.carry_basis` the matching slot
/// crash-warms this check's root and is replaced by this check's own.
fn check_feasible(
    p: &SchedProblem,
    t_hat: f64,
    opts: &BinarySearchOptions,
    carry: &mut Option<Vec<f64>>,
    basis: &mut BasisCarry,
    stats: &mut SearchStats,
) -> Option<ServingPlan> {
    let mut tspan = telemetry::span("planner.iterate", "planner");
    // pallas-lint: allow(D002, deadline read feeds the degradation ladder and stats, not the plan bits)
    let t0 = Instant::now();
    let checks_before = stats.feasibility_checks;
    let before = (
        stats.pivots,
        stats.warm_solves,
        stats.cold_solves,
        stats.basis_roots,
    );
    let plan = check_feasible_inner(p, t_hat, opts, carry, basis, stats);
    // One record per actual check (a problem whose feasibility model
    // cannot even be built runs no check and records nothing).
    if stats.feasibility_checks > checks_before {
        let it = IterateStat {
            t_hat,
            feasible: plan.is_some(),
            pivots: stats.pivots - before.0,
            warm_solves: stats.warm_solves - before.1,
            cold_solves: stats.cold_solves - before.2,
            from_basis: stats.basis_roots > before.3,
        };
        stats.iterates.push(it);
        if telemetry::enabled() {
            telemetry::count("planner.iterates", 1);
            telemetry::count(
                if it.from_basis {
                    "planner.basis_hits"
                } else {
                    "planner.basis_misses"
                },
                1,
            );
            telemetry::observe("planner.iterate_ms", t0.elapsed().as_secs_f64() * 1e3);
            tspan.tag("t_hat", t_hat);
            tspan.tag("feasible", it.feasible);
            tspan.tag("from_basis", it.from_basis);
            tspan.tag("pivots", it.pivots);
            tspan.tag("warm_solves", it.warm_solves);
            tspan.tag("cold_solves", it.cold_solves);
        }
    }
    plan
}

fn check_feasible_inner(
    p: &SchedProblem,
    t_hat: f64,
    opts: &BinarySearchOptions,
    carry: &mut Option<Vec<f64>>,
    basis: &mut BasisCarry,
    stats: &mut SearchStats,
) -> Option<ServingPlan> {
    let model = build_feasibility(p, t_hat)?;
    stats.feasibility_checks += 1;
    match opts.feasibility {
        Feasibility::Exact => {
            let ints: Vec<usize> =
                (model.y_base..model.lp.num_vars).collect();
            // Plans over budget are useless: let the B&B prune on it.
            let milp_opts = MilpOptions {
                cutoff: p.budget + 1e-6,
                ..opts.milp.clone()
            };
            let root_basis = if opts.carry_basis {
                basis.exact.as_ref()
            } else {
                None
            };
            let (res, mstats, terminal) = solve_milp_session(
                &model.lp,
                &ints,
                &milp_opts,
                carry.as_deref(),
                root_basis,
            );
            stats.absorb_milp(&mstats);
            if opts.carry_basis {
                if let Some(snap) = terminal {
                    basis.exact = Some(snap);
                }
            }
            match res {
                MilpResult::Optimal { x, objective } | MilpResult::Feasible { x, objective, .. } => {
                    if objective <= p.budget + 1e-6 {
                        let plan = extract(p, &model, &x, t_hat);
                        plan.validate(p, 1e-4).ok()?;
                        *carry = Some(x);
                        Some(plan)
                    } else {
                        None
                    }
                }
                _ => None,
            }
        }
        Feasibility::Knapsack => {
            // LP relaxation with the budget as a hard row (the exact mode
            // checks cost via the objective instead), then *iterative
            // rounding* on one factorized arena ([`round_integral`]): the
            // root crash-warms from the basis carried across T̂ iterates,
            // and each fix is a native bound change dual-re-solved in
            // place. Conservative but close to exact, and each step is a
            // handful of pivots instead of a cold LP.
            //
            // The rounding loop is this mode's stand-in for the exact MILP,
            // so it reports under the same `milp.solve` span name (the
            // exact arm gets its span inside `solve_milp_session`).
            let mut tspan = telemetry::span("milp.solve", "milp");
            tspan.tag("mode", "knapsack");
            let mut lp = model.lp.clone();
            lp.add(
                p.candidates
                    .iter()
                    .enumerate()
                    .map(|(ci, c)| (model.y_base + ci, c.cost))
                    .collect(),
                Cmp::Le,
                p.budget,
            );
            let ncand = p.candidates.len();
            let root_basis = if opts.carry_basis {
                basis.knapsack.as_ref()
            } else {
                None
            };
            let (rounded, rstats, terminal) = round_integral(
                &lp,
                model.y_base..model.y_base + ncand,
                root_basis,
                4 * ncand + 8,
            );
            stats.absorb_rounding(&rstats);
            if opts.carry_basis {
                if let Some(snap) = terminal {
                    basis.knapsack = Some(snap);
                }
            }
            tspan.tag("rounds", rstats.rounds);
            let y: Vec<u32> = rounded?.into_iter().map(|v| v as u32).collect();
            if !within_resources(p, &y) {
                return None;
            }
            // Re-solve the assignment LP with y fixed to confirm coverage
            // within T̂ (the conservative verification step).
            let plan = solve_assignment_fixed_y(p, &y, t_hat, stats)?;
            plan.validate(p, 1e-4).ok()?;
            Some(plan)
        }
    }
}

/// Build a plan from an exact feasibility MILP solution.
fn extract(p: &SchedProblem, model: &FeasModel, x: &[f64], _t_hat: f64) -> ServingPlan {
    let nw = p.demands.iter().map(|d| d.len()).max().unwrap_or(0);
    let mut entries = Vec::new();
    for (ci, _) in p.candidates.iter().enumerate() {
        let k = x[model.y_base + ci].round() as u32;
        if k == 0 {
            continue;
        }
        let mut fractions = vec![0.0; nw];
        for (w, &v) in model.x_index[ci].iter().enumerate() {
            if v != usize::MAX {
                fractions[w] = x[v];
            }
        }
        entries.push(PlanEntry {
            candidate: ci,
            replicas: k,
            fractions,
        });
    }
    let mut plan = ServingPlan {
        entries,
        makespan: 0.0,
    };
    plan.makespan = plan.evaluate_makespan(p);
    plan
}

fn within_resources(p: &SchedProblem, y: &[u32]) -> bool {
    let cost: f64 = y
        .iter()
        .enumerate()
        .map(|(ci, &k)| k as f64 * p.candidates[ci].cost)
        .sum();
    if cost > p.budget + 1e-9 {
        return false;
    }
    let mut used = vec![0u64; p.num_gpu_types];
    for (ci, &k) in y.iter().enumerate() {
        for (n, &d) in p.candidates[ci].gpu_counts.iter().enumerate() {
            // Widen before multiplying: with unlimited-availability
            // baselines y can reach the sentinel range, where d * k
            // overflows u32.
            used[n] += d as u64 * k as u64;
        }
    }
    used.iter().zip(&p.avail).all(|(&u, &a)| u <= a as u64)
}

/// With the composition fixed, find fractions x minimising the realised
/// makespan (an LP: min T' s.t. assignment + Σ x λ/h ≤ T'·y). Returns a plan
/// when the realised makespan ≤ T̂ (+ small slack). Pass `t_hat = ∞` for an
/// unconditional re-assignment — the orchestrator's incremental repair uses
/// this to re-spread workloads over the replicas that survive a market
/// event.
pub fn solve_assignment_fixed_y(
    p: &SchedProblem,
    y: &[u32],
    t_hat: f64,
    stats: &mut SearchStats,
) -> Option<ServingPlan> {
    // Variables: x per (active candidate, feasible workload) + T'.
    let mut x_index: Vec<Vec<usize>> = vec![Vec::new(); p.candidates.len()];
    let mut next = 0usize;
    for (ci, c) in p.candidates.iter().enumerate() {
        x_index[ci] = c
            .h
            .iter()
            .enumerate()
            .map(|(w, &h)| {
                if y[ci] > 0 && h > 0.0 && p.demands[c.model][w] > 0.0 {
                    let v = next;
                    next += 1;
                    v
                } else {
                    usize::MAX
                }
            })
            .collect();
    }
    let t_var = next;
    let mut lp = Lp::new(t_var + 1);
    lp.set_objective(t_var, 1.0);
    for v in 0..t_var {
        lp.set_bounds(v, 0.0, 1.0); // fractions are shares
    }
    for (m, dm) in p.demands.iter().enumerate() {
        for (w, &lambda) in dm.iter().enumerate() {
            if lambda <= 0.0 {
                continue;
            }
            let terms: Vec<(usize, f64)> = p
                .candidates
                .iter()
                .enumerate()
                .filter(|(_, c)| c.model == m)
                .filter_map(|(ci, _)| {
                    let v = x_index[ci][w];
                    (v != usize::MAX).then_some((v, 1.0))
                })
                .collect();
            if terms.is_empty() {
                return None;
            }
            lp.add(terms, Cmp::Eq, 1.0);
        }
    }
    for (ci, c) in p.candidates.iter().enumerate() {
        if y[ci] == 0 {
            continue;
        }
        let mut terms: Vec<(usize, f64)> = Vec::new();
        for (w, &h) in c.h.iter().enumerate() {
            let v = x_index[ci][w];
            if v == usize::MAX {
                continue;
            }
            terms.push((v, p.demands[c.model][w] / (y[ci] as f64 * h)));
        }
        if terms.is_empty() {
            continue;
        }
        terms.push((t_var, -1.0));
        lp.add(terms, Cmp::Le, 0.0);
    }
    stats.lp_solves += 1;
    let LpResult::Optimal { x, objective } = solve_counted(&lp, &mut stats.pivots) else {
        return None;
    };
    // Allow 1% slack over T̂ — the rounding added capacity, so the realised
    // makespan is usually *below* T̂.
    if objective > t_hat * 1.01 + 1e-9 {
        return None;
    }
    let mut entries = Vec::new();
    let nw = p.demands.iter().map(|d| d.len()).max().unwrap_or(0);
    for (ci, &k) in y.iter().enumerate() {
        if k == 0 {
            continue;
        }
        let mut fractions = vec![0.0; nw];
        for (w, &v) in x_index[ci].iter().enumerate() {
            if v != usize::MAX {
                fractions[w] = x[v];
            }
        }
        entries.push(PlanEntry {
            candidate: ci,
            replicas: k,
            fractions,
        });
    }
    let mut plan = ServingPlan {
        entries,
        makespan: 0.0,
    };
    plan.makespan = plan.evaluate_makespan(p);
    Some(plan)
}

/// Post-search polish: greedily spend leftover budget on extra replicas as
/// long as the re-optimised assignment improves the makespan. This closes
/// most of the gap the conservative knapsack rounding leaves (the paper's
/// <1% deviation claim holds only with the solution refined to use the
/// budget).
pub fn polish_plan(
    p: &SchedProblem,
    plan: ServingPlan,
    stats: &mut SearchStats,
) -> ServingPlan {
    let mut y = vec![0u32; p.candidates.len()];
    for e in &plan.entries {
        y[e.candidate] += e.replicas;
    }
    let mut best = plan;
    loop {
        let mut improved = false;
        // Candidates ordered by aggregate throughput density (most valuable
        // first) so the first improving addition is usually the best one.
        let mut order: Vec<usize> = (0..p.candidates.len()).collect();
        order.sort_by(|&a, &b| {
            let da = p.candidates[a].h.iter().sum::<f64>() / p.candidates[a].cost.max(1e-9);
            let db = p.candidates[b].h.iter().sum::<f64>() / p.candidates[b].cost.max(1e-9);
            db.partial_cmp(&da)
                .expect("candidate densities are finite profiler-table ratios")
        });
        for ci in order {
            y[ci] += 1;
            if !within_resources(p, &y) {
                y[ci] -= 1;
                continue;
            }
            if let Some(candidate_plan) =
                solve_assignment_fixed_y(p, &y, f64::INFINITY, stats)
            {
                if candidate_plan.makespan < best.makespan * 0.999 {
                    best = candidate_plan;
                    improved = true;
                    break;
                }
            }
            y[ci] -= 1;
        }
        if !improved {
            return best;
        }
    }
}

/// Run Algorithm 1. Returns the best plan found and search statistics.
///
/// This is the one free entry point kept on the module; every consumer
/// outside `sched::` goes through [`crate::sched::planner`] instead, and
/// cross-call warm state (incumbent + terminal basis) lives in
/// [`crate::sched::planner::PlannerSession`].
pub fn solve_binary_search(
    p: &SchedProblem,
    opts: &BinarySearchOptions,
) -> (Option<ServingPlan>, SearchStats) {
    let mut basis = BasisCarry::default();
    solve_binary_search_core(p, opts, None, None, &mut basis)
}

/// Algorithm 1 with the full warm surface: `warm_upper` is a makespan known
/// (or believed) achievable — typically the incumbent plan's makespan when
/// replanning after a market event; a feasible warm bound skips the loose
/// analytic upper bound and most of the early bisection, an infeasible one
/// costs a single extra feasibility check. `seed_plan` seeds the exact-mode
/// feasibility MILPs with a known plan: its solution vector becomes the
/// B&B's first feasible point, so pruning starts before the first branch,
/// and each feasible bisection iterate then seeds the next check (the model
/// layout is identical across T̂ values). `basis` carries the root bases
/// *across* T̂ iterates — and across whole calls when the caller is a
/// [`crate::sched::planner::PlannerSession`] — so each feasibility root
/// (exact MILP and knapsack rounding alike) is crash-warmed instead of
/// rebuilt cold.
pub(crate) fn solve_binary_search_core(
    p: &SchedProblem,
    opts: &BinarySearchOptions,
    warm_upper: Option<f64>,
    seed_plan: Option<&ServingPlan>,
    basis: &mut BasisCarry,
) -> (Option<ServingPlan>, SearchStats) {
    // pallas-lint: allow(D002, wall clock bounds the bisection time budget; the search path is clock-independent)
    let start = Instant::now();
    let mut stats = SearchStats::default();
    let Some(ub) = p.makespan_upper_bound() else {
        return (None, stats);
    };
    let mut carry: Option<Vec<f64>> = seed_plan
        .and_then(|plan| build_feasibility(p, ub).map(|model| plan_solution(&model, plan)));

    // Candidate upper bounds, tightest first: the warm start (if it is
    // tighter than the analytic bound), the analytic bound, and a widened
    // fallback for knapsack conservatism. The first feasible one defines
    // the incumbent plan.
    let mut tries: Vec<f64> = Vec::new();
    if let Some(w) = warm_upper {
        if w.is_finite() && w > 0.0 && w < ub {
            tries.push(w);
        }
    }
    tries.push(ub);
    tries.push(4.0 * ub);
    let seeded = tries.into_iter().find_map(|t| {
        check_feasible(p, t, opts, &mut carry, basis, &mut stats)
            .map(|plan| (plan.makespan.min(t), plan))
    });
    let Some((mut upper, seed_plan)) = seeded else {
        stats.elapsed = start.elapsed();
        return (None, stats);
    };
    let mut best = Some(seed_plan);
    let mut lower = p.makespan_lower_bound().min(upper);

    while upper - lower > opts.tolerance && stats.iterations < opts.max_iters {
        stats.iterations += 1;
        let t_hat = 0.5 * (upper + lower);
        match check_feasible(p, t_hat, opts, &mut carry, basis, &mut stats) {
            Some(plan) => {
                // Feasible: tighten from above. The realised makespan can be
                // far below T̂ — exploit it.
                upper = plan.makespan.min(t_hat);
                best = Some(plan);
            }
            None => {
                lower = t_hat;
            }
        }
    }

    let best = best.map(|plan| polish_plan(p, plan, &mut stats));
    stats.elapsed = start.elapsed();
    (best, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::formulation::solve_direct;
    use crate::sched::planner::{BisectionPlanner, PlanRequest, Planner};
    use crate::sched::toy::simple_example;

    #[test]
    fn binary_search_exact_matches_direct_milp_on_toy() {
        let p = simple_example();
        let (direct, _) = solve_direct(&p, &MilpOptions::default());
        let direct = direct.unwrap();
        let opts = BinarySearchOptions {
            tolerance: 0.05,
            feasibility: Feasibility::Exact,
            ..Default::default()
        };
        let (bs, stats) = solve_binary_search(&p, &opts);
        let bs = bs.unwrap();
        bs.validate(&p, 1e-4).unwrap();
        assert!(stats.iterations > 0);
        // Within tolerance of each other.
        assert!(
            (bs.makespan - direct.makespan).abs() <= 0.2,
            "bs={} direct={}",
            bs.makespan,
            direct.makespan
        );
    }

    #[test]
    fn knapsack_mode_close_to_exact() {
        let p = simple_example();
        let exact = solve_binary_search(
            &p,
            &BinarySearchOptions {
                tolerance: 0.05,
                feasibility: Feasibility::Exact,
                ..Default::default()
            },
        )
        .0
        .unwrap();
        let approx = solve_binary_search(
            &p,
            &BinarySearchOptions {
                tolerance: 0.05,
                feasibility: Feasibility::Knapsack,
                ..Default::default()
            },
        )
        .0
        .unwrap();
        approx.validate(&p, 1e-4).unwrap();
        // Paper: "deviations of less than 1%" — allow a bit more on the toy.
        assert!(
            approx.makespan <= exact.makespan * 1.10 + 0.2,
            "approx={} exact={}",
            approx.makespan,
            exact.makespan
        );
    }

    #[test]
    fn plans_respect_budget_and_availability() {
        let mut p = simple_example();
        p.budget = 6.0;
        for mode in [Feasibility::Exact, Feasibility::Knapsack] {
            let (plan, _) = solve_binary_search(
                &p,
                &BinarySearchOptions {
                    feasibility: mode,
                    tolerance: 0.1,
                    ..Default::default()
                },
            );
            let plan = plan.unwrap();
            plan.validate(&p, 1e-4).unwrap();
            assert!(plan.cost(&p) <= 6.0 + 1e-6);
        }
    }

    #[test]
    fn tighter_budget_cannot_improve_makespan() {
        let p_rich = simple_example();
        let mut p_poor = simple_example();
        p_poor.budget = 4.0;
        let opts = BinarySearchOptions {
            tolerance: 0.05,
            feasibility: Feasibility::Exact,
            ..Default::default()
        };
        let rich = solve_binary_search(&p_rich, &opts).0.unwrap();
        let poor = solve_binary_search(&p_poor, &opts).0.unwrap();
        assert!(
            poor.makespan >= rich.makespan - 0.1,
            "poor={} rich={}",
            poor.makespan,
            rich.makespan
        );
    }

    #[test]
    fn exact_mode_reports_solver_stats_and_seeding_agrees() {
        let p = simple_example();
        let opts = BinarySearchOptions {
            tolerance: 0.05,
            feasibility: Feasibility::Exact,
            ..Default::default()
        };
        let (plan, stats) = solve_binary_search(&p, &opts);
        let plan = plan.unwrap();
        assert!(stats.pivots > 0, "no pivots recorded");
        assert!(stats.milp_nodes > 0, "no B&B nodes recorded");
        // The default run carries the basis across T̂ iterates: after the
        // first check, roots come from the carried basis, and the
        // per-iterate records account for every check.
        assert!(
            stats.basis_roots > 0,
            "no root was crash-warmed across iterates"
        );
        assert_eq!(stats.iterates.len(), stats.feasibility_checks);
        assert!(!stats.iterates[0].from_basis, "first root had no carry");
        let total_pivots: u64 = stats.iterates.iter().map(|i| i.pivots).sum();
        assert!(total_pivots <= stats.pivots);
        // Replanning seeded with the incumbent must agree (within the
        // bisection tolerance) and still produce a valid plan. The warm
        // surface is the planner API: a `PlanRequest` carrying the
        // incumbent as warm bound and MILP seed.
        let mut planner = BisectionPlanner::new(opts.clone());
        let report = planner.plan(
            &PlanRequest::new(&p)
                .with_warm_upper(plan.makespan)
                .with_seed(&plan),
        );
        assert!(report.stats.pivots > 0);
        let plan2 = report.into_plan().unwrap();
        plan2.validate(&p, 1e-4).unwrap();
        assert!(
            (plan2.makespan - plan.makespan).abs() <= 0.2,
            "seeded {} vs fresh {}",
            plan2.makespan,
            plan.makespan
        );
        let warm_only = planner.plan(&PlanRequest::new(&p).with_warm_upper(plan.makespan));
        assert!(warm_only.into_plan().is_some());
    }

    #[test]
    fn basis_carry_matches_per_iterate_cold_arena() {
        // carry_basis only changes how roots are warmed, never the answer.
        let p = simple_example();
        let mk = |carry_basis: bool| BinarySearchOptions {
            tolerance: 0.05,
            feasibility: Feasibility::Exact,
            carry_basis,
            ..Default::default()
        };
        let (with, s_with) = solve_binary_search(&p, &mk(true));
        let (without, s_without) = solve_binary_search(&p, &mk(false));
        let (a, b) = (with.unwrap(), without.unwrap());
        assert!(
            (a.makespan - b.makespan).abs() <= 0.2,
            "carry {} vs cold-arena {}",
            a.makespan,
            b.makespan
        );
        assert!(s_with.basis_roots > 0);
        assert_eq!(s_without.basis_roots, 0);
    }

    #[test]
    fn knapsack_mode_carries_rounding_basis() {
        // The default (knapsack) path must also warm its roots: after the
        // first check, rounding roots crash from the carried basis, and the
        // search reports a nonzero warm-hit rate.
        let p = simple_example();
        let opts = BinarySearchOptions {
            tolerance: 0.05,
            feasibility: Feasibility::Knapsack,
            ..Default::default()
        };
        let (plan, stats) = solve_binary_search(&p, &opts);
        assert!(plan.is_some());
        assert!(stats.basis_roots > 0, "no rounding root crash-warmed");
        assert!(stats.warm_hit_rate() > 0.0);
        assert!(!stats.iterates[0].from_basis, "first root had no carry");
        assert!(
            stats.iterates.iter().any(|i| i.from_basis),
            "no iterate reported the carry"
        );
        // Carry off: every rounding root runs cold, same plan quality.
        let (plan_cold, cold) = solve_binary_search(
            &p,
            &BinarySearchOptions {
                carry_basis: false,
                ..opts
            },
        );
        assert_eq!(cold.basis_roots, 0);
        let (a, b) = (plan.unwrap(), plan_cold.unwrap());
        assert!(
            (a.makespan - b.makespan).abs() <= 0.2,
            "carry {} vs cold {}",
            a.makespan,
            b.makespan
        );
    }

    #[test]
    fn unservable_problem_returns_none() {
        let mut p = simple_example();
        p.avail = vec![0, 0, 0];
        let (plan, _) = solve_binary_search(&p, &BinarySearchOptions::default());
        assert!(plan.is_none());
    }
}
