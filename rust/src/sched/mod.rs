//! The paper's scheduling algorithm: given models, workload demands, a price
//! budget, and real-time GPU availability, produce the cost-optimal serving
//! plan — GPU composition, deployment configurations, and workload
//! assignment (§4).
//!
//! * [`enumerate`] — feasible configuration enumeration (App D heuristics);
//! * [`formulation`] — the §4.3 MILP (big-M linearised makespan) solved by
//!   our branch & bound;
//! * [`binary_search`] — Algorithm 1: binary-search-on-T with exact or
//!   knapsack-approximate feasibility checks (App F);
//! * [`planner`] — the unified planning surface: the [`planner::Planner`]
//!   trait every strategy (Algorithm 1, sessions, baselines) implements,
//!   the [`planner::PlanRequest`]/[`planner::PlanReport`] contract, and
//!   the stateful [`planner::PlannerSession`] carrying warm solver state
//!   across calls. Every consumer outside `sched::` plans through here;
//! * multi-model serving (App E) is inherent: a [`SchedProblem`] carries a
//!   list of models, each with its own demands and candidate set.

pub mod binary_search;
pub mod enumerate;
pub mod formulation;
pub mod planner;

use crate::cloud::Availability;
use crate::perf_model::ReplicaConfig;
use crate::profiler::Profile;
use crate::workload::TraceMix;

/// A candidate configuration in scheduler terms: abstract over GPU catalogs
/// so the paper's §4.2 toy example and the real profiles use the same code.
#[derive(Clone, Debug)]
pub struct Candidate {
    /// Which model this candidate serves (index into `SchedProblem::demands`).
    pub model: usize,
    /// Hourly cost `o_c`.
    pub cost: f64,
    /// GPUs used per abstract GPU type `v_c`.
    pub gpu_counts: Vec<u32>,
    /// Throughput per workload type of this model `h_{c,w}` (req/s);
    /// 0.0 = this candidate cannot serve that workload.
    pub h: Vec<f64>,
    /// Human-readable label.
    pub label: String,
    /// Optional link back to the concrete replica configuration.
    pub replica: Option<ReplicaConfig>,
}

/// A scheduling problem instance (single- or multi-model).
#[derive(Clone, Debug)]
pub struct SchedProblem {
    pub num_gpu_types: usize,
    /// Available GPUs per type `a_n`.
    pub avail: Vec<u32>,
    /// Price budget `B` ($/h).
    pub budget: f64,
    /// Request demand per model per workload type `λ_{m,w}` (request counts).
    pub demands: Vec<Vec<f64>>,
    pub candidates: Vec<Candidate>,
}

impl SchedProblem {
    /// Build a single-model problem from a profile + trace mixture +
    /// availability snapshot.
    pub fn from_profile(
        profile: &Profile,
        mix: &TraceMix,
        total_requests: f64,
        avail: &Availability,
        budget: f64,
    ) -> SchedProblem {
        Self::multi_model(
            &[(profile, mix, total_requests)],
            avail,
            budget,
        )
    }

    /// Build a multi-model problem (Appendix E): each entry is
    /// (profile, trace mixture, total requests routed to that model).
    pub fn multi_model(
        models: &[(&Profile, &TraceMix, f64)],
        avail: &Availability,
        budget: f64,
    ) -> SchedProblem {
        let mut demands = Vec::new();
        let mut candidates = Vec::new();
        for (m, (profile, mix, total)) in models.iter().enumerate() {
            demands.push(mix.demands(*total).to_vec());
            for pc in &profile.configs {
                candidates.push(Candidate {
                    model: m,
                    cost: pc.cost,
                    gpu_counts: pc.gpu_counts.to_vec(),
                    h: pc.throughput.to_vec(),
                    label: pc.label(),
                    replica: Some(pc.config.clone()),
                });
            }
        }
        SchedProblem {
            num_gpu_types: 6,
            avail: avail.counts.to_vec(),
            budget,
            demands,
            candidates,
        }
    }

    /// Total request demand across models and workloads.
    pub fn total_demand(&self) -> f64 {
        self.demands.iter().flatten().sum()
    }

    /// A trivially-valid upper bound on the makespan: serve each workload's
    /// full demand on the single cheapest feasible candidate, sequentially.
    pub fn makespan_upper_bound(&self) -> Option<f64> {
        let mut total = 0.0;
        for (m, dm) in self.demands.iter().enumerate() {
            for (w, &lambda) in dm.iter().enumerate() {
                if lambda <= 0.0 {
                    continue;
                }
                // Slowest positive-throughput affordable candidate.
                let worst = self
                    .candidates
                    .iter()
                    .filter(|c| c.model == m && c.h[w] > 0.0 && c.cost <= self.budget)
                    .map(|c| lambda / c.h[w])
                    .fold(f64::NAN, f64::max);
                if worst.is_nan() {
                    return None; // no candidate can serve this workload
                }
                total += worst;
            }
        }
        Some(total)
    }

    /// Lower bound on the makespan (App G: "the minimum possible makespan
    /// occurs when all workloads are assigned to the most efficient
    /// configuration without considering resource constraints") — here
    /// tightened with the budget: spending the whole budget on the best
    /// throughput-per-dollar candidates for each workload.
    pub fn makespan_lower_bound(&self) -> f64 {
        let mut lb: f64 = 0.0;
        // Each workload individually: even with the entire budget devoted to
        // it, time ≥ λ / (B · best h/o).
        for (m, dm) in self.demands.iter().enumerate() {
            for (w, &lambda) in dm.iter().enumerate() {
                if lambda <= 0.0 {
                    continue;
                }
                let best_density = self
                    .candidates
                    .iter()
                    .filter(|c| c.model == m && c.h[w] > 0.0)
                    .map(|c| c.h[w] / c.cost)
                    .fold(0.0, f64::max);
                if best_density > 0.0 {
                    lb = lb.max(lambda / (self.budget * best_density));
                }
            }
        }
        // All workloads together also bound it.
        let mut total_time_at_best = 0.0;
        for (m, dm) in self.demands.iter().enumerate() {
            for (w, &lambda) in dm.iter().enumerate() {
                if lambda <= 0.0 {
                    continue;
                }
                let best_density = self
                    .candidates
                    .iter()
                    .filter(|c| c.model == m && c.h[w] > 0.0)
                    .map(|c| c.h[w] / c.cost)
                    .fold(0.0, f64::max);
                if best_density > 0.0 {
                    total_time_at_best += lambda / (self.budget * best_density);
                }
            }
        }
        lb.max(total_time_at_best / 1.0_f64.max(self.demands.len() as f64 * 9.0))
    }
}

/// One activated configuration in the final plan.
#[derive(Clone, Debug)]
pub struct PlanEntry {
    pub candidate: usize,
    /// Number of replica copies `y_c`.
    pub replicas: u32,
    /// Fraction of each workload type of this candidate's model assigned
    /// here (`x_{c,w}`).
    pub fractions: Vec<f64>,
}

/// A complete serving plan (the paper's §4.1 deliverable).
#[derive(Clone, Debug)]
pub struct ServingPlan {
    pub entries: Vec<PlanEntry>,
    /// Objective value (makespan, seconds).
    pub makespan: f64,
}

impl ServingPlan {
    /// Total rental cost of the plan, $/h.
    pub fn cost(&self, p: &SchedProblem) -> f64 {
        self.entries
            .iter()
            .map(|e| e.replicas as f64 * p.candidates[e.candidate].cost)
            .sum()
    }

    /// GPUs rented per type.
    pub fn gpus_used(&self, p: &SchedProblem) -> Vec<u32> {
        let mut used = vec![0u32; p.num_gpu_types];
        for e in &self.entries {
            for (n, &d) in p.candidates[e.candidate].gpu_counts.iter().enumerate() {
                used[n] += d * e.replicas;
            }
        }
        used
    }

    /// Recompute the actual makespan of the plan from first principles
    /// (max over entries of Σ_w x·λ_w/(y·h)).
    pub fn evaluate_makespan(&self, p: &SchedProblem) -> f64 {
        let mut t: f64 = 0.0;
        for e in &self.entries {
            let c = &p.candidates[e.candidate];
            let mut tc = 0.0;
            for (w, &frac) in e.fractions.iter().enumerate() {
                if frac > 1e-12 {
                    let lambda = p.demands[c.model][w];
                    tc += frac * lambda / (e.replicas as f64 * c.h[w]);
                }
            }
            t = t.max(tc);
        }
        t
    }

    /// Validate the plan: full coverage of every workload, budget and
    /// availability respected, no assignment to zero-throughput pairs.
    pub fn validate(&self, p: &SchedProblem, tol: f64) -> Result<(), String> {
        // Coverage per (model, workload).
        for (m, dm) in p.demands.iter().enumerate() {
            for (w, &lambda) in dm.iter().enumerate() {
                if lambda <= 0.0 {
                    continue;
                }
                let cover: f64 = self
                    .entries
                    .iter()
                    .filter(|e| p.candidates[e.candidate].model == m)
                    .map(|e| e.fractions[w])
                    .sum();
                if (cover - 1.0).abs() > tol {
                    return Err(format!("model {m} workload {w}: coverage {cover}"));
                }
            }
        }
        // Budget.
        let cost = self.cost(p);
        if cost > p.budget + tol {
            return Err(format!("cost {cost} exceeds budget {}", p.budget));
        }
        // Availability.
        let used = self.gpus_used(p);
        for (n, (&u, &a)) in used.iter().zip(&p.avail).enumerate() {
            if u > a {
                return Err(format!("gpu type {n}: used {u} > avail {a}"));
            }
        }
        // No assignment onto h=0.
        for e in &self.entries {
            let c = &p.candidates[e.candidate];
            if e.replicas == 0 {
                if e.fractions.iter().any(|&f| f > tol) {
                    return Err("assignment to inactive config".to_string());
                }
                continue;
            }
            for (w, &f) in e.fractions.iter().enumerate() {
                if f > tol && c.h[w] <= 0.0 {
                    return Err(format!("assignment to infeasible pair (c,{w})"));
                }
            }
        }
        Ok(())
    }

    /// Percentage of GPUs (by count) from each abstract type, for the
    /// paper's "51% data-center GPUs" style analyses.
    pub fn composition_fractions(&self, p: &SchedProblem) -> Vec<f64> {
        let used = self.gpus_used(p);
        let total: u32 = used.iter().sum();
        if total == 0 {
            return vec![0.0; p.num_gpu_types];
        }
        used.iter().map(|&u| u as f64 / total as f64).collect()
    }
}

/// Helper shared by examples/benches: the proportional ("assigned to each
/// GPU in proportion to its processing rate") makespan used in the paper's
/// §4.2 Cases 1 and 2.
pub fn proportional_makespan(p: &SchedProblem, replicas: &[(usize, u32)]) -> f64 {
    // System-wide throughput per workload = sum of replica rates; the time
    // is the sum over workloads of demand / aggregate rate (the paper's
    // λ1/C1 + λ2/C2 formula).
    let model = 0;
    let nw = p.demands[model].len();
    let mut total_time = 0.0;
    for w in 0..nw {
        let lambda = p.demands[model][w];
        if lambda <= 0.0 {
            continue;
        }
        let rate: f64 = replicas
            .iter()
            .map(|&(c, y)| y as f64 * p.candidates[c].h[w])
            .sum();
        total_time += lambda / rate;
    }
    total_time
}

#[cfg(test)]
pub(crate) mod toy {
    use super::*;

    /// The paper's §4.2 / Appendix C toy instance: three GPU types, two
    /// workloads (λ = 80, 20), four candidate configurations.
    pub fn simple_example() -> SchedProblem {
        let mk = |cost: f64, counts: Vec<u32>, h: Vec<f64>, label: &str| Candidate {
            model: 0,
            cost,
            gpu_counts: counts,
            h,
            label: label.to_string(),
            replica: None,
        };
        SchedProblem {
            num_gpu_types: 3,
            avail: vec![2, 2, 2],
            budget: 8.0,
            demands: vec![vec![80.0, 20.0]],
            candidates: vec![
                mk(4.0, vec![1, 0, 0], vec![1.0, 1.2], "t1"),
                mk(2.0, vec![0, 1, 0], vec![0.9, 0.9], "t2"),
                mk(2.0, vec![0, 0, 1], vec![0.3, 0.5], "t3"),
                mk(4.0, vec![0, 2, 0], vec![2.4, 1.5], "t2-tp2"),
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::toy::simple_example;
    use super::*;

    #[test]
    fn proportional_makespans_match_paper_appendix_c() {
        let p = simple_example();
        // Case 1, composition 1: 1×t1 + 1×t2 + 1×t3 → 44.05 s.
        let t1 = proportional_makespan(&p, &[(0, 1), (1, 1), (2, 1)]);
        assert!((t1 - 44.05).abs() < 0.05, "t1={t1}");
        // Case 1, composition 2: 1×t1 + 2×t2 → 35.24 s.
        let t2 = proportional_makespan(&p, &[(0, 1), (1, 2)]);
        assert!((t2 - 35.24).abs() < 0.05, "t2={t2}");
        // Case 2, configuration 2: t1 + TP(2×t2) → 30.94 s.
        let t3 = proportional_makespan(&p, &[(0, 1), (3, 1)]);
        assert!((t3 - 30.94).abs() < 0.05, "t3={t3}");
    }

    #[test]
    fn plan_validation_catches_violations() {
        let p = simple_example();
        // Valid plan: t1 + tp2, paper's Case-3 fractions.
        let plan = ServingPlan {
            entries: vec![
                PlanEntry {
                    candidate: 0,
                    replicas: 1,
                    fractions: vec![0.15, 1.0],
                },
                PlanEntry {
                    candidate: 3,
                    replicas: 1,
                    fractions: vec![0.85, 0.0],
                },
            ],
            makespan: 28.67,
        };
        assert!(plan.validate(&p, 1e-9).is_ok());
        assert!((plan.cost(&p) - 8.0).abs() < 1e-12);
        assert_eq!(plan.gpus_used(&p), vec![1, 2, 0]);
        // Paper's Case 3 number.
        let t = plan.evaluate_makespan(&p);
        assert!((t - 28.67).abs() < 0.05, "t={t}");

        // Broken coverage.
        let mut bad = plan.clone();
        bad.entries[0].fractions[0] = 0.10;
        assert!(bad.validate(&p, 1e-9).is_err());

        // Over budget.
        let mut expensive = plan.clone();
        expensive.entries[0].replicas = 2;
        assert!(expensive.validate(&p, 1e-6).is_err());
    }

    #[test]
    fn bounds_bracket_reasonable_makespans() {
        let p = simple_example();
        let ub = p.makespan_upper_bound().unwrap();
        let lb = p.makespan_lower_bound();
        assert!(lb > 0.0);
        assert!(ub > lb, "ub={ub} lb={lb}");
        // The paper's best plan (28.43–28.67 s) must lie within the bounds.
        assert!(lb <= 28.7 && ub >= 28.4, "lb={lb} ub={ub}");
    }

    #[test]
    fn from_profile_maps_candidates() {
        use crate::perf_model::{ModelSpec, PerfModel};
        use crate::sched::enumerate::EnumOptions;
        let profile = crate::profiler::Profile::build(
            &ModelSpec::llama3_8b(),
            &PerfModel::default(),
            &EnumOptions::default(),
        );
        let p = SchedProblem::from_profile(
            &profile,
            &TraceMix::trace1(),
            1000.0,
            &crate::cloud::availability(1),
            30.0,
        );
        assert_eq!(p.candidates.len(), profile.configs.len());
        assert_eq!(p.demands.len(), 1);
        assert!((p.total_demand() - 1000.0).abs() < 1e-9);
        assert_eq!(p.num_gpu_types, 6);
    }
}
