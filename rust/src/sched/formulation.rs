//! The §4.3 MILP formulation, solved directly by branch & bound.
//!
//! The paper's makespan constraint Σ_w x_{c,w}·λ_w/(y_c·h_{c,w}) ≤ T is
//! nonlinear in (x, y, T). Following the standard linearisation, we expand
//! each candidate into *copy-count variants*: variant (c, k) means "k
//! replicas of configuration c" with a **binary** activation y_{c,k}.
//! Copy counts are powers of two, so any integer replica count composes
//! from active variants while keeping the expansion logarithmic. The
//! makespan row becomes big-M linear:
//!
//!   Σ_w x_{c,k,w}·λ_w/(k·h_{c,w}) ≤ T + M·(1 − y_{c,k})
//!
//! with M = the makespan upper bound. Activation coupling is the aggregated
//! exact form Σ_w x_{c,k,w} ≤ W·y_{c,k} (exact because each x ≤ 1 by the
//! assignment rows). Budget and availability rows sum k·y over variants.
//!
//! This is the "plain MILP" arm of Figure 9; the production path is
//! [`super::binary_search`].

use super::{PlanEntry, SchedProblem, ServingPlan};
use crate::milp::{solve_milp, Cmp, Lp, MilpOptions, MilpResult, MilpStats};

/// Variable layout for the direct MILP.
pub struct DirectMilp {
    pub lp: Lp,
    pub integer_vars: Vec<usize>,
    /// (candidate index, copy count) per variant.
    pub variants: Vec<(usize, u32)>,
    /// x-variable index per (variant, workload) — usize::MAX when the pair
    /// is infeasible (h = 0) and no variable exists.
    pub x_index: Vec<Vec<usize>>,
    /// Index of the makespan variable T.
    pub t_var: usize,
    pub big_m: f64,
}

/// Build the direct MILP for a problem. Returns None when some workload has
/// no feasible candidate at all.
pub fn build_direct(p: &SchedProblem) -> Option<DirectMilp> {
    let big_m = p.makespan_upper_bound()?;

    // ---- variants: (candidate, k) with k ∈ {1,2,4,...} -------------------
    let mut variants: Vec<(usize, u32)> = Vec::new();
    for (ci, c) in p.candidates.iter().enumerate() {
        if c.cost <= 0.0 {
            continue;
        }
        let by_budget = (p.budget / c.cost).floor() as u32;
        let by_avail = c
            .gpu_counts
            .iter()
            .enumerate()
            .filter(|&(_, &d)| d > 0)
            .map(|(n, &d)| p.avail[n] / d)
            .min()
            .unwrap_or(0);
        let max_copies = by_budget.min(by_avail);
        let mut k = 1u32;
        while k <= max_copies {
            variants.push((ci, k));
            k *= 2;
        }
    }
    if variants.is_empty() {
        return None;
    }

    // ---- variable layout --------------------------------------------------
    // [x vars...][y vars...][T]
    let mut x_index: Vec<Vec<usize>> = Vec::with_capacity(variants.len());
    let mut next = 0usize;
    for &(ci, _) in &variants {
        let c = &p.candidates[ci];
        let row: Vec<usize> = c
            .h
            .iter()
            .map(|&h| {
                if h > 0.0 {
                    let v = next;
                    next += 1;
                    v
                } else {
                    usize::MAX
                }
            })
            .collect();
        x_index.push(row);
    }
    let y_base = next;
    let t_var = y_base + variants.len();
    let num_vars = t_var + 1;

    let mut lp = Lp::new(num_vars);
    lp.set_objective(t_var, 1.0);
    // Workload fractions are shares: x ∈ [0, 1] natively.
    for v in 0..y_base {
        lp.set_bounds(v, 0.0, 1.0);
    }

    // Assignment: ∀(m,w) with λ>0: Σ over variants of model m: x = 1.
    for (m, dm) in p.demands.iter().enumerate() {
        for (w, &lambda) in dm.iter().enumerate() {
            if lambda <= 0.0 {
                continue;
            }
            let mut terms = Vec::new();
            for (vi, &(ci, _)) in variants.iter().enumerate() {
                if p.candidates[ci].model == m && x_index[vi][w] != usize::MAX {
                    terms.push((x_index[vi][w], 1.0));
                }
            }
            if terms.is_empty() {
                return None; // workload unservable
            }
            lp.add(terms, Cmp::Eq, 1.0);
        }
    }

    // Makespan big-M rows + aggregated activation coupling.
    for (vi, &(ci, k)) in variants.iter().enumerate() {
        let c = &p.candidates[ci];
        let y = y_base + vi;
        let mut time_terms: Vec<(usize, f64)> = Vec::new();
        let mut couple_terms: Vec<(usize, f64)> = Vec::new();
        for (w, &h) in c.h.iter().enumerate() {
            if h <= 0.0 {
                continue;
            }
            let lambda = p.demands[c.model][w];
            if lambda <= 0.0 {
                continue;
            }
            let xv = x_index[vi][w];
            time_terms.push((xv, lambda / (k as f64 * h)));
            couple_terms.push((xv, 1.0));
        }
        // Σ x·λ/(k·h) − T − M·(1−y) ≤ 0  ⇒  Σ ... − T + M·y ≤ M.
        let mut row = time_terms;
        row.push((t_var, -1.0));
        row.push((y, big_m));
        lp.add(row, Cmp::Le, big_m);
        // Σ_w x ≤ W·y.
        if !couple_terms.is_empty() {
            let w_count = couple_terms.len() as f64;
            let mut row = couple_terms;
            row.push((y, -w_count));
            lp.add(row, Cmp::Le, 0.0);
        }
        // y binary: a native variable bound, not a row — branching on y is
        // then a pure bound tightening in the warm-started B&B.
        lp.set_bounds(y, 0.0, 1.0);
    }

    // Budget: Σ k·o_c·y ≤ B.
    lp.add(
        variants
            .iter()
            .enumerate()
            .map(|(vi, &(ci, k))| (y_base + vi, k as f64 * p.candidates[ci].cost))
            .collect(),
        Cmp::Le,
        p.budget,
    );

    // Availability: ∀n: Σ k·d_n(c)·y ≤ a_n.
    for n in 0..p.num_gpu_types {
        let terms: Vec<(usize, f64)> = variants
            .iter()
            .enumerate()
            .filter(|(_, &(ci, _))| p.candidates[ci].gpu_counts[n] > 0)
            .map(|(vi, &(ci, k))| {
                (
                    y_base + vi,
                    (k * p.candidates[ci].gpu_counts[n]) as f64,
                )
            })
            .collect();
        if !terms.is_empty() {
            lp.add(terms, Cmp::Le, p.avail[n] as f64);
        }
    }

    let integer_vars: Vec<usize> = (0..variants.len()).map(|vi| y_base + vi).collect();
    Some(DirectMilp {
        lp,
        integer_vars,
        variants,
        x_index,
        t_var,
        big_m,
    })
}

/// Solve the problem with the direct MILP. Returns the plan and solver
/// statistics (for the Figure 9 comparison).
pub fn solve_direct(
    p: &SchedProblem,
    opts: &MilpOptions,
) -> (Option<ServingPlan>, MilpStats) {
    let Some(milp) = build_direct(p) else {
        return (None, MilpStats::default());
    };
    let (result, stats) = solve_milp(&milp.lp, &milp.integer_vars, opts);
    let plan = match result {
        MilpResult::Optimal { x, .. } | MilpResult::Feasible { x, .. } => {
            Some(extract_plan(p, &milp, &x))
        }
        _ => None,
    };
    (plan, stats)
}

/// Merge variant activations back into per-candidate plan entries.
fn extract_plan(p: &SchedProblem, milp: &DirectMilp, x: &[f64]) -> ServingPlan {
    let y_base = milp.x_index.iter().flatten().filter(|&&v| v != usize::MAX).count();
    let nw = p.demands.iter().map(|d| d.len()).max().unwrap_or(0);
    // Accumulate replicas and *absolute demand shares* per candidate.
    let mut replicas = vec![0u32; p.candidates.len()];
    let mut shares = vec![vec![0.0f64; nw]; p.candidates.len()];
    for (vi, &(ci, k)) in milp.variants.iter().enumerate() {
        let active = x[y_base + vi] > 0.5;
        if !active {
            continue;
        }
        replicas[ci] += k;
        for (w, &xv) in milp.x_index[vi].iter().enumerate() {
            if xv != usize::MAX {
                shares[ci][w] += x[xv];
            }
        }
    }
    let mut entries = Vec::new();
    for (ci, &reps) in replicas.iter().enumerate() {
        if reps == 0 {
            continue;
        }
        entries.push(PlanEntry {
            candidate: ci,
            replicas: reps,
            fractions: shares[ci].clone(),
        });
    }
    let mut plan = ServingPlan {
        entries,
        makespan: 0.0,
    };
    plan.makespan = plan.evaluate_makespan(p);
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::toy::simple_example;

    #[test]
    fn direct_milp_solves_paper_toy_optimally() {
        let p = simple_example();
        let (plan, stats) = solve_direct(&p, &MilpOptions::default());
        let plan = plan.expect("plan");
        plan.validate(&p, 1e-6).unwrap();
        // The LP-optimal assignment on {t1, TP(2×t2)} gives ~28.43 s; the
        // paper's hand-rounded assignment gives 28.67 s. The optimum must be
        // ≤ the paper's number and ≥ a sane bound.
        assert!(
            plan.makespan <= 28.68 && plan.makespan >= 27.0,
            "makespan={} entries={:?}",
            plan.makespan,
            plan.entries
        );
        assert!(stats.nodes >= 1);
        // It must beat every §4.2 intermediate case.
        assert!(plan.makespan < 30.94);
    }

    #[test]
    fn budget_binds() {
        let mut p = simple_example();
        p.budget = 4.0; // only one of {t1, tp2} or two cheap GPUs
        let (plan, _) = solve_direct(&p, &MilpOptions::default());
        let plan = plan.expect("plan");
        plan.validate(&p, 1e-6).unwrap();
        assert!(plan.cost(&p) <= 4.0 + 1e-9);
        // Strictly worse than the 8 $/h optimum.
        assert!(plan.makespan > 28.7, "makespan={}", plan.makespan);
    }

    #[test]
    fn availability_binds() {
        let mut p = simple_example();
        // Without t2 GPUs, the TP config and t2 singles vanish.
        p.avail = vec![2, 0, 2];
        let (plan, _) = solve_direct(&p, &MilpOptions::default());
        let plan = plan.expect("plan");
        plan.validate(&p, 1e-6).unwrap();
        let used = plan.gpus_used(&p);
        assert_eq!(used[1], 0);
    }

    #[test]
    fn infeasible_workload_returns_none() {
        let mut p = simple_example();
        // Make workload 1 unservable by every candidate.
        for c in &mut p.candidates {
            c.h[1] = 0.0;
        }
        let (plan, _) = solve_direct(&p, &MilpOptions::default());
        assert!(plan.is_none());
    }

    #[test]
    fn zero_demand_workload_ignored() {
        let mut p = simple_example();
        p.demands[0][1] = 0.0;
        let (plan, _) = solve_direct(&p, &MilpOptions::default());
        let plan = plan.expect("plan");
        plan.validate(&p, 1e-6).unwrap();
        // All capacity should go to w0: makespan ≈ 80 / 3.4 ≈ 23.5 s with
        // t1 + tp2 (or better).
        assert!(plan.makespan < 28.0, "makespan={}", plan.makespan);
    }

    #[test]
    fn multi_model_formulation() {
        // Two models sharing the GPU pool: model 1 copies the toy, model 2
        // has half the demand and can only use t2/t3-based configs.
        let base = simple_example();
        let mut p = base.clone();
        p.demands.push(vec![40.0, 10.0]);
        let mut extra: Vec<_> = base.candidates[1..3]
            .iter()
            .cloned()
            .map(|mut c| {
                c.model = 1;
                c.label = format!("{}-m1", c.label);
                c
            })
            .collect();
        p.candidates.append(&mut extra);
        p.budget = 12.0;
        let (plan, _) = solve_direct(&p, &MilpOptions::default());
        let plan = plan.expect("plan");
        plan.validate(&p, 1e-6).unwrap();
        // Coverage validation inside validate() already checks both models.
        assert!(plan.makespan > 0.0);
    }
}
