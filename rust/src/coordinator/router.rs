//! Request router: decides which replica serves each incoming request.
//!
//! Policies:
//! * `RoundRobin` — the paper's ablation baseline;
//! * `Jsq` — join-shortest-queue load balancing;
//! * `WorkloadAware` — the paper's workload assignment: per workload type,
//!   replicas are chosen with probabilities proportional to the plan's
//!   `x_{c,w}` fractions, tie-breaking by shortest queue among the top
//!   candidates.
//!
//! Orthogonally to the placement policy, an [`AdmissionPolicy`] decides
//! whether a request is accepted at all: with a `max_queue` bound, requests
//! arriving while every replica's queue is at the bound are shed instead of
//! queued (route via [`Router::route_admitted`]). Shedding keeps tail
//! latency bounded during overload at the cost of lost requests — the
//! trade-off the cost-efficiency experiments need to surface rather than
//! hide inside unbounded queues.

use crate::telemetry;
use crate::util::rng::Xoshiro256;

/// Admission control applied before placement. `Default` admits everything.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AdmissionPolicy {
    /// Shed a request when the chosen replica already holds this many queued
    /// requests. `None` = unbounded queues (historical behavior).
    pub max_queue: Option<usize>,
}

impl AdmissionPolicy {
    pub fn unlimited() -> AdmissionPolicy {
        AdmissionPolicy { max_queue: None }
    }

    pub fn capped(max_queue: usize) -> AdmissionPolicy {
        AdmissionPolicy {
            max_queue: Some(max_queue),
        }
    }

    /// Can a replica currently holding `load` queued requests accept one more?
    #[inline]
    pub fn admits(&self, load: usize) -> bool {
        match self.max_queue {
            Some(cap) => load < cap,
            None => true,
        }
    }
}

#[derive(Clone, Debug)]
pub enum RouterPolicy {
    RoundRobin,
    Jsq,
    /// fractions[w][r] = share of workload type w that replica r should get.
    WorkloadAware { fractions: Vec<Vec<f64>> },
}

pub struct Router {
    policy: RouterPolicy,
    admission: AdmissionPolicy,
    rr_next: usize,
    rng: Xoshiro256,
    num_replicas: usize,
    shed: u64,
}

impl Router {
    pub fn new(policy: RouterPolicy, num_replicas: usize, seed: u64) -> Router {
        Self::with_admission(policy, AdmissionPolicy::unlimited(), num_replicas, seed)
    }

    pub fn with_admission(
        policy: RouterPolicy,
        admission: AdmissionPolicy,
        num_replicas: usize,
        seed: u64,
    ) -> Router {
        if let RouterPolicy::WorkloadAware { fractions } = &policy {
            for (w, fr) in fractions.iter().enumerate() {
                assert_eq!(
                    fr.len(),
                    num_replicas,
                    "workload {w}: fraction arity mismatch"
                );
            }
        }
        Router {
            policy,
            admission,
            rr_next: 0,
            rng: Xoshiro256::seed_from_u64(seed),
            num_replicas,
            shed: 0,
        }
    }

    /// Requests shed by [`Router::route_admitted`] so far.
    pub fn shed_count(&self) -> u64 {
        self.shed
    }

    pub fn admission(&self) -> AdmissionPolicy {
        self.admission
    }

    /// Choose a replica for a request of workload type `workload`, given the
    /// current queue length of each replica.
    pub fn route(&mut self, workload: usize, loads: &[usize]) -> usize {
        assert_eq!(loads.len(), self.num_replicas);
        match &self.policy {
            RouterPolicy::RoundRobin => {
                let r = self.rr_next;
                self.rr_next = (self.rr_next + 1) % self.num_replicas;
                r
            }
            RouterPolicy::Jsq => {
                let min = *loads.iter().min().unwrap();
                // Deterministic tie-break: lowest index.
                loads.iter().position(|&l| l == min).unwrap()
            }
            RouterPolicy::WorkloadAware { fractions } => {
                let fr = fractions
                    .get(workload)
                    .unwrap_or_else(|| panic!("no fractions for workload {workload}"));
                let total: f64 = fr.iter().sum();
                if total <= 0.0 {
                    // Fall back to JSQ.
                    let min = *loads.iter().min().unwrap();
                    return loads.iter().position(|&l| l == min).unwrap();
                }
                self.rng.weighted_index(fr)
            }
        }
    }

    /// Like [`Router::route`], but subject to the admission policy: returns
    /// `None` (and counts a shed) when every replica's queue is at the
    /// bound. When only the policy's preferred replica is full, the request
    /// overflows to the least-loaded admissible replica (lowest index on
    /// ties) rather than being shed — shedding is a last resort.
    pub fn route_admitted(&mut self, workload: usize, loads: &[usize]) -> Option<usize> {
        assert_eq!(loads.len(), self.num_replicas);
        if !loads.iter().any(|&l| self.admission.admits(l)) {
            self.shed += 1;
            telemetry::count("router.shed", 1);
            return None;
        }
        let pick = self.route(workload, loads);
        if self.admission.admits(loads[pick]) {
            return Some(pick);
        }
        loads
            .iter()
            .enumerate()
            .filter(|&(_, &l)| self.admission.admits(l))
            .min_by_key(|&(i, &l)| (l, i))
            .map(|(i, _)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RouterPolicy::RoundRobin, 3, 1);
        let picks: Vec<usize> = (0..6).map(|_| r.route(0, &[0, 0, 0])).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn jsq_picks_least_loaded() {
        let mut r = Router::new(RouterPolicy::Jsq, 3, 1);
        assert_eq!(r.route(0, &[5, 2, 9]), 1);
        assert_eq!(r.route(0, &[1, 1, 0]), 2);
        // Tie → lowest index.
        assert_eq!(r.route(0, &[3, 3, 3]), 0);
    }

    #[test]
    fn workload_aware_follows_fractions() {
        let fractions = vec![
            vec![1.0, 0.0], // workload 0 → replica 0 only
            vec![0.2, 0.8], // workload 1 → mostly replica 1
        ];
        let mut r = Router::new(RouterPolicy::WorkloadAware { fractions }, 2, 7);
        let mut counts = [0usize; 2];
        for _ in 0..1000 {
            counts[r.route(1, &[0, 0])] += 1;
        }
        let frac1 = counts[1] as f64 / 1000.0;
        assert!((frac1 - 0.8).abs() < 0.05, "frac1={frac1}");
        for _ in 0..100 {
            assert_eq!(r.route(0, &[9, 0]), 0, "w0 pinned to replica 0");
        }
    }

    #[test]
    fn workload_aware_zero_row_falls_back_to_jsq() {
        let fractions = vec![vec![0.0, 0.0]];
        let mut r = Router::new(RouterPolicy::WorkloadAware { fractions }, 2, 3);
        assert_eq!(r.route(0, &[4, 1]), 1);
    }

    #[test]
    fn unlimited_admission_never_sheds() {
        let mut r = Router::new(RouterPolicy::Jsq, 2, 1);
        for _ in 0..100 {
            assert_eq!(r.route_admitted(0, &[1_000_000, 1_000_001]), Some(0));
        }
        assert_eq!(r.shed_count(), 0);
    }

    #[test]
    fn capped_admission_sheds_when_all_full() {
        let mut r =
            Router::with_admission(RouterPolicy::Jsq, AdmissionPolicy::capped(4), 3, 1);
        // Room somewhere → admitted at the least-loaded replica.
        assert_eq!(r.route_admitted(0, &[4, 2, 4]), Some(1));
        // Everyone at the cap → shed.
        assert_eq!(r.route_admitted(0, &[4, 4, 4]), None);
        assert_eq!(r.route_admitted(0, &[5, 9, 4]), None);
        assert_eq!(r.shed_count(), 2);
    }

    #[test]
    fn full_preferred_replica_overflows_before_shedding() {
        // Workload 0 is pinned to replica 0; when replica 0 is at the cap
        // the request overflows to the admissible least-loaded replica.
        let fractions = vec![vec![1.0, 0.0, 0.0]];
        let mut r = Router::with_admission(
            RouterPolicy::WorkloadAware { fractions },
            AdmissionPolicy::capped(2),
            3,
            7,
        );
        assert_eq!(r.route_admitted(0, &[2, 1, 0]), Some(2));
        assert_eq!(r.shed_count(), 0);
    }

    #[test]
    fn round_robin_skips_full_replicas() {
        let mut r = Router::with_admission(
            RouterPolicy::RoundRobin,
            AdmissionPolicy::capped(1),
            2,
            1,
        );
        // Replica 0 (the round-robin pick) is full → overflow to replica 1.
        assert_eq!(r.route_admitted(0, &[1, 0]), Some(1));
    }

    #[test]
    fn admission_policy_predicates() {
        assert!(AdmissionPolicy::unlimited().admits(usize::MAX - 1));
        let capped = AdmissionPolicy::capped(3);
        assert!(capped.admits(2));
        assert!(!capped.admits(3));
        assert_eq!(AdmissionPolicy::default(), AdmissionPolicy::unlimited());
    }

    #[test]
    #[should_panic(expected = "arity mismatch")]
    fn arity_checked() {
        let _ = Router::new(
            RouterPolicy::WorkloadAware {
                fractions: vec![vec![1.0]],
            },
            2,
            1,
        );
    }
}
