//! Request router: decides which replica serves each incoming request.
//!
//! Policies:
//! * `RoundRobin` — the paper's ablation baseline;
//! * `Jsq` — join-shortest-queue load balancing;
//! * `WorkloadAware` — the paper's workload assignment: per workload type,
//!   replicas are chosen with probabilities proportional to the plan's
//!   `x_{c,w}` fractions, tie-breaking by shortest queue among the top
//!   candidates.

use crate::util::rng::Xoshiro256;

#[derive(Clone, Debug)]
pub enum RouterPolicy {
    RoundRobin,
    Jsq,
    /// fractions[w][r] = share of workload type w that replica r should get.
    WorkloadAware { fractions: Vec<Vec<f64>> },
}

pub struct Router {
    policy: RouterPolicy,
    rr_next: usize,
    rng: Xoshiro256,
    num_replicas: usize,
}

impl Router {
    pub fn new(policy: RouterPolicy, num_replicas: usize, seed: u64) -> Router {
        if let RouterPolicy::WorkloadAware { fractions } = &policy {
            for (w, fr) in fractions.iter().enumerate() {
                assert_eq!(
                    fr.len(),
                    num_replicas,
                    "workload {w}: fraction arity mismatch"
                );
            }
        }
        Router {
            policy,
            rr_next: 0,
            rng: Xoshiro256::seed_from_u64(seed),
            num_replicas,
        }
    }

    /// Choose a replica for a request of workload type `workload`, given the
    /// current queue length of each replica.
    pub fn route(&mut self, workload: usize, loads: &[usize]) -> usize {
        assert_eq!(loads.len(), self.num_replicas);
        match &self.policy {
            RouterPolicy::RoundRobin => {
                let r = self.rr_next;
                self.rr_next = (self.rr_next + 1) % self.num_replicas;
                r
            }
            RouterPolicy::Jsq => {
                let min = *loads.iter().min().unwrap();
                // Deterministic tie-break: lowest index.
                loads.iter().position(|&l| l == min).unwrap()
            }
            RouterPolicy::WorkloadAware { fractions } => {
                let fr = fractions
                    .get(workload)
                    .unwrap_or_else(|| panic!("no fractions for workload {workload}"));
                let total: f64 = fr.iter().sum();
                if total <= 0.0 {
                    // Fall back to JSQ.
                    let min = *loads.iter().min().unwrap();
                    return loads.iter().position(|&l| l == min).unwrap();
                }
                self.rng.weighted_index(fr)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RouterPolicy::RoundRobin, 3, 1);
        let picks: Vec<usize> = (0..6).map(|_| r.route(0, &[0, 0, 0])).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn jsq_picks_least_loaded() {
        let mut r = Router::new(RouterPolicy::Jsq, 3, 1);
        assert_eq!(r.route(0, &[5, 2, 9]), 1);
        assert_eq!(r.route(0, &[1, 1, 0]), 2);
        // Tie → lowest index.
        assert_eq!(r.route(0, &[3, 3, 3]), 0);
    }

    #[test]
    fn workload_aware_follows_fractions() {
        let fractions = vec![
            vec![1.0, 0.0], // workload 0 → replica 0 only
            vec![0.2, 0.8], // workload 1 → mostly replica 1
        ];
        let mut r = Router::new(RouterPolicy::WorkloadAware { fractions }, 2, 7);
        let mut counts = [0usize; 2];
        for _ in 0..1000 {
            counts[r.route(1, &[0, 0])] += 1;
        }
        let frac1 = counts[1] as f64 / 1000.0;
        assert!((frac1 - 0.8).abs() < 0.05, "frac1={frac1}");
        for _ in 0..100 {
            assert_eq!(r.route(0, &[9, 0]), 0, "w0 pinned to replica 0");
        }
    }

    #[test]
    fn workload_aware_zero_row_falls_back_to_jsq() {
        let fractions = vec![vec![0.0, 0.0]];
        let mut r = Router::new(RouterPolicy::WorkloadAware { fractions }, 2, 3);
        assert_eq!(r.route(0, &[4, 1]), 1);
    }

    #[test]
    #[should_panic(expected = "arity mismatch")]
    fn arity_checked() {
        let _ = Router::new(
            RouterPolicy::WorkloadAware {
                fractions: vec![vec![1.0]],
            },
            2,
            1,
        );
    }
}
