//! The real serving coordinator: request router, continuous batcher, and
//! the serving loop that drives the PJRT engine (see [`crate::runtime`]).
//!
//! This is the L3 request path of the three-layer stack — pure rust, no
//! python. The planner (`crate::sched`) decides *what* to deploy; this
//! module *serves* with it.

pub mod batcher;
pub mod router;
pub mod server;

pub use batcher::{Batcher, Completion, ServeRequest};
pub use router::{AdmissionPolicy, Router, RouterPolicy};
pub use server::{serve, synth_requests, ServeReport, ServerOptions};
