//! Continuous batcher for one (logical) replica of the real serving engine.
//!
//! Mirrors vLLM's iteration loop: admit queued requests into free slots
//! (prefill each once), then advance all active slots one token per decode
//! round, retiring slots that reach their output budget.
//!
//! Slots are **fixed-index**: a request keeps its slot until it finishes, so
//! the server can keep the batched KV cache resident and splice only the
//! admitted slot's stripes instead of re-gathering the whole cache every
//! step (the §Perf optimisation).

use std::collections::VecDeque;

/// A request submitted to the serving engine.
#[derive(Clone, Debug)]
pub struct ServeRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    /// Number of tokens to generate.
    pub max_new: usize,
    /// Workload type index (0..9) for routing/reporting.
    pub workload: usize,
    /// Arrival offset from serving start, seconds.
    pub arrival_offset_s: f64,
}

/// A completed request with its generated tokens and timing.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub arrival_offset_s: f64,
    pub first_token_s: f64,
    pub finish_s: f64,
}

/// An active slot: a request mid-generation.
pub struct ActiveSlot {
    pub request: ServeRequest,
    /// Current KV write position (= valid length).
    pub position: usize,
    pub generated: Vec<i32>,
    pub last_token: i32,
    pub first_token_s: f64,
}

/// Per-replica continuous batching state with fixed-index slots.
pub struct Batcher {
    pub queue: VecDeque<ServeRequest>,
    pub slots: Vec<Option<ActiveSlot>>,
    /// Hard cap from the model's max_seq: a slot must finish before its
    /// position exceeds this.
    pub max_position: usize,
    pub completed: Vec<Completion>,
}

impl Batcher {
    pub fn new(max_slots: usize, max_position: usize) -> Batcher {
        Batcher {
            queue: VecDeque::new(),
            slots: (0..max_slots).map(|_| None).collect(),
            max_position,
            completed: Vec::new(),
        }
    }

    pub fn submit(&mut self, req: ServeRequest) {
        self.queue.push_back(req);
    }

    pub fn active_count(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Total load (queued + active) for routing decisions.
    pub fn load(&self) -> usize {
        self.queue.len() + self.active_count()
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || self.active_count() > 0
    }

    fn free_slot(&self) -> Option<usize> {
        self.slots.iter().position(|s| s.is_none())
    }

    /// Requests that can be admitted right now (free slots and room for
    /// prompt + generation within max_position). Oversized requests are
    /// dropped with an empty completion rather than wedging the queue.
    pub fn admissible(&mut self) -> Vec<ServeRequest> {
        let mut out = Vec::new();
        let free = self.slots.iter().filter(|s| s.is_none()).count();
        while out.len() < free {
            let Some(front) = self.queue.front() else {
                break;
            };
            if front.prompt.len() + front.max_new + 1 > self.max_position {
                let req = self.queue.pop_front().unwrap();
                self.completed.push(Completion {
                    id: req.id,
                    tokens: Vec::new(),
                    arrival_offset_s: req.arrival_offset_s,
                    first_token_s: f64::NAN,
                    finish_s: f64::NAN,
                });
                continue;
            }
            out.push(self.queue.pop_front().unwrap());
        }
        out
    }

    /// Install a prefilled request into a free slot; returns the slot index.
    pub fn activate(&mut self, request: ServeRequest, first_token: i32, now_s: f64) -> usize {
        let idx = self.free_slot().expect("no free slot");
        let position = request.prompt.len();
        self.slots[idx] = Some(ActiveSlot {
            generated: vec![first_token],
            last_token: first_token,
            first_token_s: now_s,
            position,
            request,
        });
        idx
    }

    /// After a decode round produced `next_tokens[slot]` for every occupied
    /// slot: append tokens, retire finished slots. `next_tokens` is indexed
    /// by slot (entries for empty slots ignored). Returns retired slots.
    pub fn advance(&mut self, next_tokens: &[i32], now_s: f64) -> Vec<usize> {
        let mut retired = Vec::new();
        let max_position = self.max_position;
        for (idx, slot) in self.slots.iter_mut().enumerate() {
            let Some(s) = slot else { continue };
            s.generated.push(next_tokens[idx]);
            s.last_token = next_tokens[idx];
            s.position += 1;
            let done = s.generated.len() >= s.request.max_new
                || s.position + 1 >= max_position;
            if done {
                self.completed.push(Completion {
                    id: s.request.id,
                    tokens: std::mem::take(&mut s.generated),
                    arrival_offset_s: s.request.arrival_offset_s,
                    first_token_s: s.first_token_s,
                    finish_s: now_s,
                });
                retired.push(idx);
                *slot = None;
            }
        }
        retired
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt_len: usize, max_new: usize) -> ServeRequest {
        ServeRequest {
            id,
            prompt: vec![1; prompt_len],
            max_new,
            workload: 0,
            arrival_offset_s: 0.0,
        }
    }

    #[test]
    fn admission_respects_slots() {
        let mut b = Batcher::new(2, 256);
        for i in 0..5 {
            b.submit(req(i, 16, 4));
        }
        let adm = b.admissible();
        assert_eq!(adm.len(), 2);
        for r in adm {
            b.activate(r, 7, 0.0);
        }
        assert_eq!(b.admissible().len(), 0);
        assert_eq!(b.load(), 5);
    }

    #[test]
    fn slots_keep_fixed_indices() {
        let mut b = Batcher::new(3, 256);
        b.submit(req(1, 8, 1)); // finishes after first round
        b.submit(req(2, 8, 5));
        b.submit(req(3, 8, 5));
        for r in b.admissible() {
            b.activate(r, 10, 0.0);
        }
        // Slot 0 holds request 1 and retires in round 1.
        let retired = b.advance(&[11, 12, 13], 0.1);
        assert_eq!(retired, vec![0]);
        assert!(b.slots[0].is_none());
        // Requests 2 and 3 stay at slots 1 and 2.
        assert_eq!(b.slots[1].as_ref().unwrap().request.id, 2);
        assert_eq!(b.slots[2].as_ref().unwrap().request.id, 3);
        // New admission reuses slot 0.
        b.submit(req(4, 8, 5));
        for r in b.admissible() {
            let idx = b.activate(r, 20, 0.2);
            assert_eq!(idx, 0);
        }
    }

    #[test]
    fn oversized_request_dropped_not_hung() {
        let mut b = Batcher::new(2, 32);
        b.submit(req(1, 30, 10)); // 30 + 10 + 1 > 32
        let adm = b.admissible();
        assert!(adm.is_empty());
        assert_eq!(b.completed.len(), 1);
        assert!(b.completed[0].tokens.is_empty());
    }

    #[test]
    fn advance_retires_on_budget() {
        let mut b = Batcher::new(4, 256);
        b.submit(req(1, 16, 2));
        b.submit(req(2, 16, 3));
        for r in b.admissible() {
            b.activate(r, 5, 0.1);
        }
        let retired = b.advance(&[8, 9, 0, 0], 0.2);
        assert_eq!(retired, vec![0]);
        assert_eq!(b.completed[0].id, 1);
        assert_eq!(b.completed[0].tokens, vec![5, 8]);
        let retired = b.advance(&[0, 11, 0, 0], 0.3);
        assert_eq!(retired, vec![1]);
        assert_eq!(b.active_count(), 0);
        assert_eq!(b.completed[1].tokens, vec![5, 9, 11]);
    }

    #[test]
    fn position_advances_with_tokens() {
        let mut b = Batcher::new(1, 256);
        b.submit(req(1, 4, 3));
        for r in b.admissible() {
            b.activate(r, 42, 0.0);
        }
        assert_eq!(b.slots[0].as_ref().unwrap().position, 4);
        b.advance(&[43], 0.1);
        assert_eq!(b.slots[0].as_ref().unwrap().position, 5);
    }
}
