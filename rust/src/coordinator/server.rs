//! The real serving loop: router + per-replica continuous batchers driving
//! the PJRT engine. Single OS thread (PJRT handles intra-op parallelism and
//! the xla wrapper types are not Send), with R *logical* replicas
//! multiplexed — the same structure a multi-GPU deployment would shard
//! across processes.
//!
//! §Perf: the batched KV cache is *resident* per replica — requests hold
//! fixed slot indices, admissions splice one slot's stripes, and decode
//! rounds hand the previous output cache straight back as input. No
//! per-step gather/scatter.

use super::batcher::{Batcher, ServeRequest};
use super::router::{Router, RouterPolicy};
use crate::metrics::LatencyRecorder;
use crate::runtime::kv::{BatchAssembler, SlotCache};
use crate::runtime::Engine;
use anyhow::Result;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct ServerOptions {
    pub num_replicas: usize,
    /// In-flight requests per replica (rounded down to a decode bucket).
    pub max_slots: usize,
    pub router: RouterPolicy,
    pub seed: u64,
    /// If false, arrival offsets are ignored (as-fast-as-possible replay).
    pub respect_arrivals: bool,
}

impl Default for ServerOptions {
    fn default() -> Self {
        Self {
            num_replicas: 2,
            max_slots: 4,
            router: RouterPolicy::Jsq,
            seed: 0x5EDE,
            respect_arrivals: false,
        }
    }
}

/// Serving report (the e2e example prints this).
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub completed: usize,
    pub dropped: usize,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub tokens_generated: usize,
    pub tokens_per_s: f64,
    pub latency: LatencyRecorder,
    pub ttft: LatencyRecorder,
    pub per_replica_requests: Vec<usize>,
}

/// One replica's engine-side state: batcher + resident batched cache.
struct ReplicaState {
    batcher: Batcher,
    /// Resident batched KV cache, [L,2,B,T,KH,HD] flattened, B = bucket.
    cache: Vec<f32>,
}

/// Serve a batch of requests to completion on `engine`.
pub fn serve(
    engine: &Engine,
    requests: Vec<ServeRequest>,
    opts: &ServerOptions,
) -> Result<ServeReport> {
    let dims = engine.dims().clone();
    let asm = BatchAssembler::new(&dims);
    // Slot count = the largest decode bucket ≤ requested max_slots (the
    // decode executable runs at this fixed batch every round).
    let bucket = engine
        .decode_bucket_for(1)
        .map(|_| {
            engine
                .decode_buckets()
                .into_iter()
                .filter(|&b| b <= opts.max_slots.max(1))
                .max()
                .unwrap_or_else(|| engine.decode_buckets()[0])
        })
        .expect("no decode buckets");
    let mut replicas: Vec<ReplicaState> = (0..opts.num_replicas)
        .map(|_| ReplicaState {
            batcher: Batcher::new(bucket, dims.max_seq),
            cache: vec![0f32; asm.batched_len(bucket)],
        })
        .collect();
    let mut router = Router::new(opts.router.clone(), opts.num_replicas, opts.seed);

    let mut pending: Vec<ServeRequest> = requests;
    pending.sort_by(|a, b| a.arrival_offset_s.partial_cmp(&b.arrival_offset_s).unwrap());
    let total = pending.len();
    let mut pending = pending.into_iter().peekable();

    let start = Instant::now();
    let mut per_replica_requests = vec![0usize; opts.num_replicas];
    let mut tokens_generated = 0usize;

    loop {
        let now = start.elapsed().as_secs_f64();
        // Deliver arrivals.
        while let Some(req) = pending.peek() {
            if !opts.respect_arrivals || req.arrival_offset_s <= now {
                let req = pending.next().unwrap();
                let loads: Vec<usize> = replicas.iter().map(|r| r.batcher.load()).collect();
                let target = router.route(req.workload, &loads);
                per_replica_requests[target] += 1;
                replicas[target].batcher.submit(req);
            } else {
                break;
            }
        }

        let mut progressed = false;
        for rep in replicas.iter_mut() {
            if !rep.batcher.has_work() {
                continue;
            }
            progressed = true;
            let now = start.elapsed().as_secs_f64();
            // Admit + prefill: splice each new slot's stripes into the
            // resident cache.
            for req in rep.batcher.admissible() {
                let (logits, cache_data) = engine.prefill(&req.prompt)?;
                let first = Engine::argmax(&logits);
                let position = req.prompt.len();
                let idx = rep.batcher.activate(req, first, now);
                let slot = SlotCache::new(cache_data, position);
                asm.splice_slot(&mut rep.cache, &slot, idx, bucket);
            }
            // One decode round over the resident cache.
            if rep.batcher.active_count() > 0 {
                let mut tokens = vec![0i32; bucket];
                let mut positions = vec![0i32; bucket];
                for (idx, slot) in rep.batcher.slots.iter().enumerate() {
                    if let Some(s) = slot {
                        tokens[idx] = s.last_token;
                        positions[idx] = s.position as i32;
                    }
                }
                let (logits, new_cache) =
                    engine.decode(bucket, &tokens, &rep.cache, &positions)?;
                rep.cache = new_cache;
                let mut next = vec![0i32; bucket];
                let mut active = 0usize;
                for (idx, slot) in rep.batcher.slots.iter().enumerate() {
                    if slot.is_some() {
                        next[idx] =
                            Engine::argmax(&logits[idx * dims.vocab..(idx + 1) * dims.vocab]);
                        active += 1;
                    }
                }
                tokens_generated += active;
                let now = start.elapsed().as_secs_f64();
                rep.batcher.advance(&next, now);
            }
        }

        let done: usize = replicas.iter().map(|r| r.batcher.completed.len()).sum();
        if done >= total {
            break;
        }
        if !progressed {
            if pending.peek().is_some() {
                // Waiting for the next arrival.
                std::thread::sleep(std::time::Duration::from_millis(1));
            } else {
                break;
            }
        }
    }

    // ---- report ---------------------------------------------------------
    let wall_s = start.elapsed().as_secs_f64();
    let mut latency = LatencyRecorder::new();
    let mut ttft = LatencyRecorder::new();
    let mut completed = 0usize;
    let mut dropped = 0usize;
    for rep in &replicas {
        for c in &rep.batcher.completed {
            if c.tokens.is_empty() {
                dropped += 1;
                continue;
            }
            completed += 1;
            latency.record(c.finish_s, c.finish_s - c.arrival_offset_s.max(0.0));
            ttft.record(c.first_token_s, c.first_token_s - c.arrival_offset_s.max(0.0));
        }
    }
    Ok(ServeReport {
        completed,
        dropped,
        wall_s,
        throughput_rps: completed as f64 / wall_s,
        tokens_generated,
        tokens_per_s: tokens_generated as f64 / wall_s,
        latency,
        ttft,
        per_replica_requests,
    })
}

/// Build a synthetic serving workload: bucket-aligned prompts with
/// deterministic token content, mixed across prompt/output shapes in the
/// spirit of the paper's workload types (scaled to the tiny model).
pub fn synth_requests(n: usize, seed: u64, buckets: &[usize], vocab: usize) -> Vec<ServeRequest> {
    use crate::util::rng::Xoshiro256;
    let mut rng = Xoshiro256::seed_from_u64(seed);
    // (prompt bucket index, output tokens) — long-in/short-out through
    // short-in/long-out, mirroring the paper's 9-type grid at tiny scale.
    let shapes: [(usize, usize); 9] = [
        (3, 48),
        (3, 24),
        (3, 4),
        (2, 48),
        (2, 24),
        (2, 4),
        (0, 48),
        (0, 24),
        (0, 4),
    ];
    (0..n as u64)
        .map(|id| {
            let w = rng.index(9);
            let (bidx, max_new) = shapes[w];
            let plen = buckets[bidx.min(buckets.len() - 1)];
            let prompt: Vec<i32> = (0..plen)
                .map(|_| rng.range_u64(1, vocab as u64 - 1) as i32)
                .collect();
            ServeRequest {
                id,
                prompt,
                max_new,
                workload: w,
                arrival_offset_s: 0.0,
            }
        })
        .collect()
}
