//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO text + weights.bin + manifest.json) and executes prefill/decode
//! steps on the PJRT CPU client. Python never runs on this path.

pub mod kv;

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Model dimensions from the manifest (mirrors python TinyConfig).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelDims {
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    pub param_count: usize,
}

/// One parameter tensor's location in weights.bin.
#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

/// Parsed manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dims: ModelDims,
    pub params: Vec<ParamEntry>,
    /// (seq bucket, file name), ascending.
    pub prefill: Vec<(usize, String)>,
    /// (batch bucket, file name), ascending.
    pub decode: Vec<(usize, String)>,
    pub weights_f32_count: usize,
}

impl Manifest {
    pub fn parse(j: &Json) -> Result<Manifest> {
        let md = j.get("model");
        let u = |k: &str| -> Result<usize> {
            md.get(k)
                .as_usize()
                .ok_or_else(|| anyhow!("manifest: missing model.{k}"))
        };
        let dims = ModelDims {
            vocab: u("vocab")?,
            hidden: u("hidden")?,
            layers: u("layers")?,
            heads: u("heads")?,
            kv_heads: u("kv_heads")?,
            head_dim: u("head_dim")?,
            max_seq: u("max_seq")?,
            param_count: u("param_count")?,
        };
        let params = j
            .get("params")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest: params"))?
            .iter()
            .map(|p| {
                Ok(ParamEntry {
                    name: p
                        .get("name")
                        .as_str()
                        .ok_or_else(|| anyhow!("param name"))?
                        .to_string(),
                    shape: p
                        .get("shape")
                        .as_arr()
                        .ok_or_else(|| anyhow!("param shape"))?
                        .iter()
                        .map(|v| v.as_usize().ok_or_else(|| anyhow!("shape dim")))
                        .collect::<Result<_>>()?,
                    offset: p
                        .get("offset")
                        .as_usize()
                        .ok_or_else(|| anyhow!("param offset"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let buckets = |key: &str, dim: &str| -> Result<Vec<(usize, String)>> {
            let mut out = j
                .get(key)
                .as_arr()
                .ok_or_else(|| anyhow!("manifest: {key}"))?
                .iter()
                .map(|b| {
                    Ok((
                        b.get(dim)
                            .as_usize()
                            .ok_or_else(|| anyhow!("{key}.{dim}"))?,
                        b.get("file")
                            .as_str()
                            .ok_or_else(|| anyhow!("{key}.file"))?
                            .to_string(),
                    ))
                })
                .collect::<Result<Vec<_>>>()?;
            out.sort_by_key(|(k, _)| *k);
            Ok(out)
        };
        Ok(Manifest {
            dims,
            params,
            prefill: buckets("prefill", "seq")?,
            decode: buckets("decode", "batch")?,
            weights_f32_count: j
                .get("weights_f32_count")
                .as_usize()
                .ok_or_else(|| anyhow!("weights_f32_count"))?,
        })
    }
}

/// The PJRT engine: compiled executables + resident weights.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    /// Weight literals in manifest order.
    params: Vec<xla::Literal>,
    prefill_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    decode_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    artifacts_dir: PathBuf,
}

impl Engine {
    /// Load manifest + weights and compile every bucket executable.
    pub fn load(artifacts_dir: &Path) -> Result<Engine> {
        let manifest_text = std::fs::read_to_string(artifacts_dir.join("manifest.json"))
            .with_context(|| {
                format!(
                    "reading {}/manifest.json — run `make artifacts`",
                    artifacts_dir.display()
                )
            })?;
        let manifest = Manifest::parse(
            &Json::parse(&manifest_text).map_err(|e| anyhow!("manifest.json: {e}"))?,
        )?;

        let client = xla::PjRtClient::cpu()?;

        // ---- weights ------------------------------------------------------
        let blob = std::fs::read(artifacts_dir.join("weights.bin"))
            .context("reading weights.bin")?;
        if blob.len() != manifest.weights_f32_count * 4 {
            bail!(
                "weights.bin is {} bytes, manifest says {}",
                blob.len(),
                manifest.weights_f32_count * 4
            );
        }
        let all: Vec<f32> = blob
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let mut params = Vec::with_capacity(manifest.params.len());
        for p in &manifest.params {
            let count: usize = p.shape.iter().product();
            let slice = &all[p.offset..p.offset + count];
            let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
            params.push(xla::Literal::vec1(slice).reshape(&dims)?);
        }

        // ---- executables -----------------------------------------------------
        let mut prefill_exes = BTreeMap::new();
        for (seq, file) in &manifest.prefill {
            prefill_exes.insert(*seq, compile_hlo(&client, &artifacts_dir.join(file))?);
        }
        let mut decode_exes = BTreeMap::new();
        for (batch, file) in &manifest.decode {
            decode_exes.insert(*batch, compile_hlo(&client, &artifacts_dir.join(file))?);
        }

        Ok(Engine {
            client,
            manifest,
            params,
            prefill_exes,
            decode_exes,
            artifacts_dir: artifacts_dir.to_path_buf(),
        })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    pub fn dims(&self) -> &ModelDims {
        &self.manifest.dims
    }

    /// Available prefill sequence buckets (ascending).
    pub fn prefill_buckets(&self) -> Vec<usize> {
        self.prefill_exes.keys().copied().collect()
    }

    /// Available decode batch buckets (ascending).
    pub fn decode_buckets(&self) -> Vec<usize> {
        self.decode_exes.keys().copied().collect()
    }

    /// Smallest prefill bucket that fits `len` tokens.
    pub fn prefill_bucket_for(&self, len: usize) -> Option<usize> {
        self.prefill_exes.keys().find(|&&s| s >= len).copied()
    }

    /// Smallest decode bucket that fits `batch` slots.
    pub fn decode_bucket_for(&self, batch: usize) -> Option<usize> {
        self.decode_exes.keys().find(|&&b| b >= batch).copied()
    }

    /// Size (f32 count) of a single request's KV cache slot.
    pub fn slot_f32(&self) -> usize {
        let d = &self.manifest.dims;
        d.layers * 2 * d.max_seq * d.kv_heads * d.head_dim
    }

    /// Prefill one request. `tokens` is padded to the bucket size; the
    /// trace generator emits bucket-aligned prompts so padding is normally
    /// absent.
    ///
    /// Returns (last-position logits, per-slot KV cache [L,2,T,KH,HD]).
    pub fn prefill(&self, tokens: &[i32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let bucket = self
            .prefill_bucket_for(tokens.len())
            .ok_or_else(|| anyhow!("prompt of {} tokens exceeds buckets", tokens.len()))?;
        let exe = &self.prefill_exes[&bucket];
        let mut padded = tokens.to_vec();
        padded.resize(bucket, 0);
        let d = &self.manifest.dims;
        let tokens_lit =
            xla::Literal::vec1(padded.as_slice()).reshape(&[1, bucket as i64])?;
        let cache_dims = [
            d.layers as i64,
            2,
            1,
            d.max_seq as i64,
            d.kv_heads as i64,
            d.head_dim as i64,
        ];
        let zeros = vec![0f32; self.slot_f32()];
        let zero_cache = xla::Literal::vec1(zeros.as_slice()).reshape(&cache_dims)?;
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.push(&tokens_lit);
        inputs.push(&zero_cache);
        let result = exe.execute::<&xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let (logits, cache) = result.to_tuple2()?;
        Ok((logits.to_vec::<f32>()?, cache.to_vec::<f32>()?))
    }

    /// One decode step over `bucket` slots.
    ///
    /// `cache` is the batched cache [L,2,B,T,KH,HD] flattened; `tokens` and
    /// `positions` have length B = bucket. Returns (logits [B*vocab],
    /// updated cache).
    pub fn decode(
        &self,
        bucket: usize,
        tokens: &[i32],
        cache: &[f32],
        positions: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let exe = self
            .decode_exes
            .get(&bucket)
            .ok_or_else(|| anyhow!("no decode bucket {bucket}"))?;
        let d = &self.manifest.dims;
        if tokens.len() != bucket || positions.len() != bucket {
            bail!("decode arity mismatch");
        }
        if cache.len() != self.slot_f32() * bucket {
            bail!(
                "cache len {} != {} for bucket {bucket}",
                cache.len(),
                self.slot_f32() * bucket
            );
        }
        let tokens_lit = xla::Literal::vec1(tokens);
        let cache_lit = xla::Literal::vec1(cache).reshape(&[
            d.layers as i64,
            2,
            bucket as i64,
            d.max_seq as i64,
            d.kv_heads as i64,
            d.head_dim as i64,
        ])?;
        let pos_lit = xla::Literal::vec1(positions);
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.push(&tokens_lit);
        inputs.push(&cache_lit);
        inputs.push(&pos_lit);
        let result = exe.execute::<&xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let (logits, new_cache) = result.to_tuple2()?;
        Ok((logits.to_vec::<f32>()?, new_cache.to_vec::<f32>()?))
    }

    /// Argmax over one logits row.
    pub fn argmax(logits_row: &[f32]) -> i32 {
        let mut best = 0usize;
        for (i, &v) in logits_row.iter().enumerate() {
            if v > logits_row[best] {
                best = i;
            }
        }
        best as i32
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

fn compile_hlo(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| anyhow!("bad path"))?,
    )?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

/// Locate the artifacts directory (tests/examples helper).
pub fn default_artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> Option<PathBuf> {
        let p = default_artifacts_dir();
        p.join("manifest.json").exists().then_some(p)
    }

    #[test]
    fn manifest_parses() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let j = Json::parse(&std::fs::read_to_string(dir.join("manifest.json")).unwrap())
            .unwrap();
        let m = Manifest::parse(&j).unwrap();
        assert_eq!(m.dims.layers, 4);
        assert_eq!(m.dims.vocab, 4096);
        assert!(!m.prefill.is_empty());
        assert!(!m.decode.is_empty());
        assert_eq!(m.params.len(), 1 + m.dims.layers * 9 + 2);
    }

    #[test]
    fn engine_prefill_decode_roundtrip() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let engine = Engine::load(&dir).unwrap();
        let dims = engine.dims().clone();
        // Prefill a 16-token prompt.
        let tokens: Vec<i32> = (1..17).collect();
        let (logits, slot_cache) = engine.prefill(&tokens).unwrap();
        assert_eq!(logits.len(), dims.vocab);
        assert_eq!(slot_cache.len(), engine.slot_f32());
        assert!(logits.iter().all(|v| v.is_finite()));
        // Cache should be non-zero in the first 16 positions of layer 0 keys
        // and zero beyond the prompt.
        let t = dims.max_seq;
        let per_pos = dims.kv_heads * dims.head_dim;
        let l0k: &[f32] = &slot_cache[0..t * per_pos];
        let head: f64 = l0k[..16 * per_pos].iter().map(|v| v.abs() as f64).sum();
        let tail: f64 = l0k[16 * per_pos..].iter().map(|v| v.abs() as f64).sum();
        assert!(head > 0.0);
        assert!(tail == 0.0, "cache written beyond prompt: {tail}");

        // One decode step at batch bucket 1.
        let next = Engine::argmax(&logits);
        let (logits2, cache2) = engine.decode(1, &[next], &slot_cache, &[16]).unwrap();
        assert_eq!(logits2.len(), dims.vocab);
        assert_eq!(cache2.len(), slot_cache.len());
        assert!(logits2.iter().all(|v| v.is_finite()));
        // Decode wrote position 16 of layer 0 keys.
        let pos16: f64 = cache2[16 * per_pos..17 * per_pos]
            .iter()
            .map(|v| v.abs() as f64)
            .sum();
        assert!(pos16 > 0.0);
    }

    #[test]
    fn decode_deterministic() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let engine = Engine::load(&dir).unwrap();
        let tokens: Vec<i32> = (10..26).collect();
        let (l1, c1) = engine.prefill(&tokens).unwrap();
        let (l2, c2) = engine.prefill(&tokens).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(c1, c2);
    }

    #[test]
    fn bucket_selection() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let engine = Engine::load(&dir).unwrap();
        assert_eq!(engine.prefill_bucket_for(10), Some(16));
        assert_eq!(engine.prefill_bucket_for(16), Some(16));
        assert_eq!(engine.prefill_bucket_for(17), Some(32));
        assert_eq!(engine.prefill_bucket_for(1000), None);
        assert_eq!(engine.decode_bucket_for(3), Some(4));
        assert_eq!(engine.decode_bucket_for(8), Some(8));
    }
}
