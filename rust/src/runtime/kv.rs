//! Host-side KV cache management for the real serving engine.
//!
//! Each in-flight request owns a *slot cache* — the `[L, 2, T, KH, HD]`
//! block produced by prefill. Before each batched decode step the active
//! slots are gathered into the executable's `[L, 2, B, T, KH, HD]` layout,
//! and scattered back afterwards. The gather/scatter respects the batch
//! axis sitting *inside* the layer/plane axes, so each (layer, plane) pair
//! contributes one contiguous `[T, KH, HD]` stripe per slot.

use super::ModelDims;

/// Bytes of KV cache one in-flight request holds *per context token*:
/// `layers × 2 planes × kv_heads × head_dim × bytes-per-element` — the same
/// stripe arithmetic [`BatchAssembler`] allocates for real slots, exposed so
/// the simulators can price how much state a live migration must move when a
/// replica is reclaimed inside its advance-notice window.
pub fn kv_bytes_per_token(
    layers: usize,
    kv_heads: usize,
    head_dim: usize,
    bytes_per_elem: f64,
) -> f64 {
    layers as f64 * 2.0 * kv_heads as f64 * head_dim as f64 * bytes_per_elem
}

/// A single request's KV cache plus generation state.
#[derive(Clone, Debug)]
pub struct SlotCache {
    /// Flattened [L, 2, T, KH, HD].
    pub data: Vec<f32>,
    /// Next write position (= current valid length).
    pub position: usize,
}

impl SlotCache {
    pub fn new(data: Vec<f32>, position: usize) -> Self {
        Self { data, position }
    }
}

/// Gather/scatter between slot caches and the batched executable layout.
pub struct BatchAssembler {
    pub layers: usize,
    /// f32 count of one (layer, plane) stripe for one slot: T × KH × HD.
    pub stripe: usize,
}

impl BatchAssembler {
    pub fn new(dims: &ModelDims) -> Self {
        Self {
            layers: dims.layers,
            stripe: dims.max_seq * dims.kv_heads * dims.head_dim,
        }
    }

    /// f32 count of a batched cache for `bucket` slots.
    pub fn batched_len(&self, bucket: usize) -> usize {
        self.layers * 2 * bucket * self.stripe
    }

    /// Gather `slots` (may be fewer than `bucket`; missing slots are
    /// zero-filled) into a batched cache.
    pub fn gather(&self, slots: &[&SlotCache], bucket: usize) -> Vec<f32> {
        assert!(slots.len() <= bucket);
        let mut out = vec![0f32; self.batched_len(bucket)];
        for (b, slot) in slots.iter().enumerate() {
            for lp in 0..self.layers * 2 {
                let src = &slot.data[lp * self.stripe..(lp + 1) * self.stripe];
                let dst_off = (lp * bucket + b) * self.stripe;
                out[dst_off..dst_off + self.stripe].copy_from_slice(src);
            }
        }
        out
    }

    /// Splice one slot's stripes into a resident batched cache at `idx`
    /// (admission path — the steady-state decode loop never re-gathers).
    pub fn splice_slot(&self, batched: &mut [f32], slot: &SlotCache, idx: usize, bucket: usize) {
        assert_eq!(batched.len(), self.batched_len(bucket));
        assert!(idx < bucket);
        for lp in 0..self.layers * 2 {
            let src = &slot.data[lp * self.stripe..(lp + 1) * self.stripe];
            let dst_off = (lp * bucket + idx) * self.stripe;
            batched[dst_off..dst_off + self.stripe].copy_from_slice(src);
        }
    }

    /// Scatter the batched cache back into the slot caches.
    pub fn scatter(&self, batched: &[f32], slots: &mut [&mut SlotCache], bucket: usize) {
        assert_eq!(batched.len(), self.batched_len(bucket));
        for (b, slot) in slots.iter_mut().enumerate() {
            for lp in 0..self.layers * 2 {
                let src_off = (lp * bucket + b) * self.stripe;
                let dst = &mut slot.data[lp * self.stripe..(lp + 1) * self.stripe];
                dst.copy_from_slice(&batched[src_off..src_off + self.stripe]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_bytes_per_token_matches_assembler_stripes() {
        // One token of KV = the per-token share of the assembler's
        // [L, 2, T, KH, HD] slot: layers × 2 × KH × HD elements.
        let d = dims();
        let asm = BatchAssembler::new(&d);
        let per_slot_f32 = asm.layers * 2 * asm.stripe;
        let per_token = kv_bytes_per_token(d.layers, d.kv_heads, d.head_dim, 4.0);
        assert_eq!(per_token * d.max_seq as f64, (per_slot_f32 * 4) as f64);
    }

    fn dims() -> ModelDims {
        ModelDims {
            vocab: 16,
            hidden: 8,
            layers: 2,
            heads: 2,
            kv_heads: 1,
            head_dim: 4,
            max_seq: 3,
            param_count: 0,
        }
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let d = dims();
        let asm = BatchAssembler::new(&d);
        let slot_len = d.layers * 2 * asm.stripe;
        let mut s1 = SlotCache::new((0..slot_len).map(|i| i as f32).collect(), 1);
        let mut s2 = SlotCache::new((0..slot_len).map(|i| 1000.0 + i as f32).collect(), 2);
        let batched = asm.gather(&[&s1, &s2], 4);
        assert_eq!(batched.len(), asm.batched_len(4));
        // Slot 0 stripe of (layer 0, plane 0) sits at offset 0.
        assert_eq!(&batched[..asm.stripe], &s1.data[..asm.stripe]);
        // Slot 1 stripe of (layer 0, plane 0) follows.
        assert_eq!(
            &batched[asm.stripe..2 * asm.stripe],
            &s2.data[..asm.stripe]
        );
        // Unused slots are zero.
        assert!(batched[2 * asm.stripe..3 * asm.stripe]
            .iter()
            .all(|&v| v == 0.0));

        // Mutate and scatter back.
        let mut modified = batched.clone();
        for v in modified.iter_mut() {
            *v += 0.5;
        }
        {
            let mut refs: Vec<&mut SlotCache> = vec![&mut s1, &mut s2];
            asm.scatter(&modified, &mut refs, 4);
        }
        assert_eq!(s1.data[0], 0.5);
        assert_eq!(s2.data[0], 1000.5);
    }

    #[test]
    fn splice_slot_equals_gather_position() {
        let d = dims();
        let asm = BatchAssembler::new(&d);
        let slot_len = d.layers * 2 * asm.stripe;
        let s1 = SlotCache::new((0..slot_len).map(|i| i as f32).collect(), 1);
        let s2 = SlotCache::new((0..slot_len).map(|i| 500.0 + i as f32).collect(), 2);
        // Reference: gather both.
        let gathered = asm.gather(&[&s1, &s2], 4);
        // Resident path: start empty, splice slots one at a time.
        let mut resident = vec![0f32; asm.batched_len(4)];
        asm.splice_slot(&mut resident, &s1, 0, 4);
        asm.splice_slot(&mut resident, &s2, 1, 4);
        assert_eq!(resident, gathered);
        // Replacing a slot overwrites only its stripes.
        let s3 = SlotCache::new(vec![9.0; slot_len], 0);
        asm.splice_slot(&mut resident, &s3, 0, 4);
        let check = asm.gather(&[&s3, &s2], 4);
        assert_eq!(resident, check);
    }

    #[test]
    fn batch_axis_inside_layers() {
        // The (layer, plane) index must stride over bucket × stripe.
        let d = dims();
        let asm = BatchAssembler::new(&d);
        let slot_len = d.layers * 2 * asm.stripe;
        let s = SlotCache::new(vec![7.0; slot_len], 0);
        let batched = asm.gather(&[&s], 2);
        // (layer 0, plane 1) of slot 0 begins at (1*2+0)*stripe.
        let off = 2 * asm.stripe;
        assert!(batched[off..off + asm.stripe].iter().all(|&v| v == 7.0));
        // The interleaved slot-1 stripe is zero.
        assert!(batched[asm.stripe..2 * asm.stripe]
            .iter()
            .all(|&v| v == 0.0));
    }
}
