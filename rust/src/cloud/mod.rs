//! Cloud GPU market model: real-time availability snapshots (Table 3),
//! a Vast.ai-style fluctuating availability generator (Figure 2), and
//! rental-cost accounting.

use crate::catalog::{GpuSpec, GpuType};
use crate::util::json::Json;
use crate::util::rng::Xoshiro256;

/// How many GPUs of each type are rentable right now.
/// Indexed by `GpuType::index()` (A6000, A40, L40, A100, H100, 4090).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Availability {
    pub counts: [u32; 6],
}

impl Availability {
    pub fn new(counts: [u32; 6]) -> Self {
        Self { counts }
    }

    pub fn of(&self, gpu: GpuType) -> u32 {
        self.counts[gpu.index()]
    }

    pub fn set(&mut self, gpu: GpuType, n: u32) {
        self.counts[gpu.index()] = n;
    }

    pub fn total(&self) -> u32 {
        self.counts.iter().sum()
    }

    /// Unlimited availability — used for the paper's homogeneous baselines,
    /// which assume an unbounded pool of the chosen GPU type (§5.1/App K).
    pub fn unlimited() -> Self {
        Self {
            counts: [u32::MAX / 4; 6],
        }
    }

    /// Availability restricted to a single GPU type (homogeneous market).
    pub fn only(gpu: GpuType, n: u32) -> Self {
        let mut counts = [0u32; 6];
        counts[gpu.index()] = n;
        Self { counts }
    }

    /// Total $/h if every available GPU were rented (an upper bound used for
    /// budget sanity checks).
    pub fn full_rental_cost(&self) -> f64 {
        GpuType::ALL
            .iter()
            .map(|&g| self.of(g) as f64 * GpuSpec::of(g).price_per_hour)
            .sum()
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            GpuType::ALL
                .iter()
                .map(|&g| (g.name().to_string(), Json::Num(self.of(g) as f64)))
                .collect(),
        )
    }
}

/// Table 3: the four real-time availability snapshots used in the paper's
/// evaluation. Column order in the paper is 4090, A40, A6000, L40, A100,
/// H100; our storage order is Table 1 order (A6000, A40, L40, A100, H100,
/// 4090), so the constructors below re-order accordingly.
pub fn table3_snapshots() -> Vec<Availability> {
    // (4090, a40, a6000, l40, a100, h100)
    let rows = [
        (16u32, 12u32, 8u32, 12u32, 6u32, 8u32),
        (32, 8, 16, 16, 7, 12),
        (32, 16, 8, 8, 32, 8),
        (24, 24, 24, 16, 4, 8),
    ];
    rows.iter()
        .map(|&(r4090, a40, a6000, l40, a100, h100)| {
            Availability::new([a6000, a40, l40, a100, h100, r4090])
        })
        .collect()
}

/// Availability snapshot by paper index (1-based: "Avail 1" .. "Avail 4").
pub fn availability(index: usize) -> Availability {
    let snaps = table3_snapshots();
    assert!(
        (1..=snaps.len()).contains(&index),
        "availability index {index} out of range 1..=4"
    );
    snaps[index - 1]
}

/// A fluctuating availability series in the spirit of Figure 2: each GPU
/// type follows a mean-reverting random walk between a floor and a ceiling,
/// with occasional shortage dips (the paper notes A40 ranged 0–32 on Vast.ai
/// within a day).
#[derive(Clone, Debug)]
pub struct MarketSim {
    rng: Xoshiro256,
    /// Long-run mean availability per type.
    mean: [f64; 6],
    /// Current level.
    level: [f64; 6],
    /// Mean-reversion strength per step.
    reversion: f64,
    /// Per-step noise sigma (in GPUs).
    sigma: f64,
    /// Probability of a shortage event per type per step.
    shortage_prob: f64,
}

impl MarketSim {
    pub fn new(seed: u64, mean: [f64; 6]) -> Self {
        Self {
            rng: Xoshiro256::seed_from_u64(seed),
            mean,
            level: mean,
            reversion: 0.2,
            sigma: 2.0,
            shortage_prob: 0.02,
        }
    }

    /// Default market calibrated so the mean levels are in the Table 3 range.
    pub fn default_market(seed: u64) -> Self {
        Self::new(seed, [14.0, 15.0, 13.0, 12.0, 9.0, 26.0])
    }

    /// Advance one step (e.g. one 15-minute tick) and return the snapshot.
    pub fn step(&mut self) -> Availability {
        let mut counts = [0u32; 6];
        for i in 0..6 {
            if self.rng.bernoulli(self.shortage_prob) {
                // Shortage event: availability collapses toward zero.
                self.level[i] *= self.rng.range_f64(0.0, 0.3);
            } else {
                let noise = self.rng.normal() * self.sigma;
                self.level[i] += self.reversion * (self.mean[i] - self.level[i]) + noise;
            }
            self.level[i] = self.level[i].clamp(0.0, 2.5 * self.mean[i]);
            counts[i] = self.level[i].round() as u32;
        }
        Availability::new(counts)
    }

    /// Generate a 24-hour series at the given tick interval.
    pub fn series(&mut self, ticks: usize) -> Vec<Availability> {
        (0..ticks).map(|_| self.step()).collect()
    }
}

/// Cost ledger for a rented composition.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RentalCost {
    /// GPUs rented per type.
    pub rented: [u32; 6],
}

impl RentalCost {
    pub fn add(&mut self, gpu: GpuType, n: u32) {
        self.rented[gpu.index()] += n;
    }

    /// Total $/h.
    pub fn per_hour(&self) -> f64 {
        GpuType::ALL
            .iter()
            .map(|&g| self.rented[g.index()] as f64 * GpuSpec::of(g).price_per_hour)
            .sum()
    }

    /// Fits within availability?
    pub fn feasible(&self, avail: &Availability) -> bool {
        GpuType::ALL
            .iter()
            .all(|&g| self.rented[g.index()] <= avail.of(g))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_reordering_correct() {
        // Avail 1 row in the paper: 4090=16, A40=12, A6000=8, L40=12,
        // A100=6, H100=8.
        let a1 = availability(1);
        assert_eq!(a1.of(GpuType::Rtx4090), 16);
        assert_eq!(a1.of(GpuType::A40), 12);
        assert_eq!(a1.of(GpuType::A6000), 8);
        assert_eq!(a1.of(GpuType::L40), 12);
        assert_eq!(a1.of(GpuType::A100), 6);
        assert_eq!(a1.of(GpuType::H100), 8);
        let a3 = availability(3);
        assert_eq!(a3.of(GpuType::A100), 32);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn availability_bounds_checked() {
        availability(5);
    }

    #[test]
    fn full_rental_cost_of_avail1() {
        // 8*0.83 + 12*0.55 + 12*0.83 + 6*1.75 + 8*2.99 + 16*0.53 = 66.10
        let cost = availability(1).full_rental_cost();
        assert!((cost - 66.10).abs() < 1e-9, "cost={cost}");
    }

    #[test]
    fn market_sim_stays_in_bounds_and_fluctuates() {
        let mut m = MarketSim::default_market(7);
        let series = m.series(96); // 24h at 15-min ticks
        assert_eq!(series.len(), 96);
        let a40_series: Vec<u32> = series.iter().map(|a| a.of(GpuType::A40)).collect();
        let min = *a40_series.iter().min().unwrap();
        let max = *a40_series.iter().max().unwrap();
        assert!(max > min, "series should fluctuate");
        assert!(max <= 40, "max={max}");
    }

    #[test]
    fn market_sim_deterministic() {
        let a: Vec<_> = MarketSim::default_market(3).series(10);
        let b: Vec<_> = MarketSim::default_market(3).series(10);
        assert_eq!(a, b);
    }

    #[test]
    fn rental_cost_accounting() {
        let mut r = RentalCost::default();
        r.add(GpuType::H100, 2);
        r.add(GpuType::A40, 4);
        assert!((r.per_hour() - (2.0 * 2.99 + 4.0 * 0.55)).abs() < 1e-12);
        assert!(r.feasible(&availability(1)));
        let mut r2 = RentalCost::default();
        r2.add(GpuType::A100, 7); // only 6 available in Avail 1
        assert!(!r2.feasible(&availability(1)));
    }

    #[test]
    fn only_and_unlimited() {
        let a = Availability::only(GpuType::H100, 20);
        assert_eq!(a.of(GpuType::H100), 20);
        assert_eq!(a.total(), 20);
        assert!(Availability::unlimited().of(GpuType::A40) > 1_000_000);
    }
}
