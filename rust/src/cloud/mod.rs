//! Cloud GPU market model: real-time availability snapshots (Table 3),
//! a Vast.ai-style fluctuating availability generator (Figure 2), per-type
//! price books, rental-cost accounting, and the timestamped event streams
//! feeding the online replanner ([`crate::orchestrator`]): the supply-only
//! [`MarketEventStream`] and the unified [`WorldEventStream`] that pairs
//! every market tick with a [`DemandSnapshot`] sampled from a
//! [`MixSchedule`] — the two-channel *world signal* the orchestrator
//! replans against.

use crate::catalog::{GpuSpec, GpuType};
use crate::util::json::Json;
use crate::util::rng::Xoshiro256;
use crate::workload::{DemandSnapshot, MixSchedule};

pub mod faults;

/// How many GPUs of each type are rentable right now.
/// Indexed by `GpuType::index()` (A6000, A40, L40, A100, H100, 4090).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Availability {
    pub counts: [u32; 6],
}

impl Availability {
    pub fn new(counts: [u32; 6]) -> Self {
        Self { counts }
    }

    pub fn of(&self, gpu: GpuType) -> u32 {
        self.counts[gpu.index()]
    }

    pub fn set(&mut self, gpu: GpuType, n: u32) {
        self.counts[gpu.index()] = n;
    }

    pub fn total(&self) -> u32 {
        self.counts.iter().sum()
    }

    /// Sentinel per-type count used by [`Availability::unlimited`]. Kept
    /// far below `u32::MAX` so `d * count` arithmetic cannot wrap, and
    /// detected explicitly by every cost/budget sanity check.
    pub const UNLIMITED: u32 = u32::MAX / 4;

    /// Unlimited availability — used for the paper's homogeneous baselines,
    /// which assume an unbounded pool of the chosen GPU type (§5.1/App K).
    pub fn unlimited() -> Self {
        Self {
            counts: [Self::UNLIMITED; 6],
        }
    }

    /// True when any pool carries the [`Self::UNLIMITED`] sentinel — such
    /// snapshots have no meaningful aggregate rental cost.
    pub fn is_unlimited(&self) -> bool {
        self.counts.iter().any(|&c| c >= Self::UNLIMITED)
    }

    /// Availability restricted to a single GPU type (homogeneous market).
    pub fn only(gpu: GpuType, n: u32) -> Self {
        let mut counts = [0u32; 6];
        counts[gpu.index()] = n;
        Self { counts }
    }

    /// Total $/h if every available GPU were rented (an upper bound used for
    /// budget sanity checks). Unlimited pools are treated explicitly: the
    /// sentinel count would otherwise turn into ~10⁹-dollar figures, so the
    /// bound is reported as `f64::INFINITY` instead.
    pub fn full_rental_cost(&self) -> f64 {
        self.full_rental_cost_at(&PriceBook::base())
    }

    /// [`Self::full_rental_cost`] under a fluctuating price book.
    pub fn full_rental_cost_at(&self, prices: &PriceBook) -> f64 {
        if self.is_unlimited() {
            return f64::INFINITY;
        }
        GpuType::ALL
            .iter()
            .map(|&g| self.of(g) as f64 * prices.of(g))
            .sum()
    }

    /// Budget actually spendable on this pool: `budget` clipped by the full
    /// rental cost. For unlimited pools this is just `budget` (the clip is
    /// +∞), never a sentinel-driven absurd figure.
    pub fn budget_cap(&self, budget: f64) -> f64 {
        budget.min(self.full_rental_cost())
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            GpuType::ALL
                .iter()
                .map(|&g| (g.name().to_string(), Json::Num(self.of(g) as f64)))
                .collect(),
        )
    }
}

/// Table 3: the four real-time availability snapshots used in the paper's
/// evaluation. Column order in the paper is 4090, A40, A6000, L40, A100,
/// H100; our storage order is Table 1 order (A6000, A40, L40, A100, H100,
/// 4090), so the constructors below re-order accordingly.
pub fn table3_snapshots() -> Vec<Availability> {
    // (4090, a40, a6000, l40, a100, h100)
    let rows = [
        (16u32, 12u32, 8u32, 12u32, 6u32, 8u32),
        (32, 8, 16, 16, 7, 12),
        (32, 16, 8, 8, 32, 8),
        (24, 24, 24, 16, 4, 8),
    ];
    rows.iter()
        .map(|&(r4090, a40, a6000, l40, a100, h100)| {
            Availability::new([a6000, a40, l40, a100, h100, r4090])
        })
        .collect()
}

/// Availability snapshot by paper index (1-based: "Avail 1" .. "Avail 4").
pub fn availability(index: usize) -> Availability {
    let snaps = table3_snapshots();
    assert!(
        (1..=snaps.len()).contains(&index),
        "availability index {index} out of range 1..=4"
    );
    snaps[index - 1]
}

/// A fluctuating availability series in the spirit of Figure 2: each GPU
/// type follows a mean-reverting random walk between a floor and a ceiling,
/// with occasional shortage dips (the paper notes A40 ranged 0–32 on Vast.ai
/// within a day).
#[derive(Clone, Debug)]
pub struct MarketSim {
    rng: Xoshiro256,
    /// Long-run mean availability per type.
    mean: [f64; 6],
    /// Current level.
    level: [f64; 6],
    /// Mean-reversion strength per step.
    reversion: f64,
    /// Per-step noise sigma (in GPUs).
    sigma: f64,
    /// Probability of a shortage event per type per step.
    shortage_prob: f64,
}

impl MarketSim {
    pub fn new(seed: u64, mean: [f64; 6]) -> Self {
        Self {
            rng: Xoshiro256::seed_from_u64(seed),
            mean,
            level: mean,
            reversion: 0.2,
            sigma: 2.0,
            shortage_prob: 0.02,
        }
    }

    /// Default market calibrated so the mean levels are in the Table 3 range.
    pub fn default_market(seed: u64) -> Self {
        Self::new(seed, [14.0, 15.0, 13.0, 12.0, 9.0, 26.0])
    }

    /// Advance one step (e.g. one 15-minute tick) and return the snapshot.
    pub fn step(&mut self) -> Availability {
        let mut counts = [0u32; 6];
        for i in 0..6 {
            if self.rng.bernoulli(self.shortage_prob) {
                // Shortage event: availability collapses toward zero.
                self.level[i] *= self.rng.range_f64(0.0, 0.3);
            } else {
                let noise = self.rng.normal() * self.sigma;
                self.level[i] += self.reversion * (self.mean[i] - self.level[i]) + noise;
            }
            self.level[i] = self.level[i].clamp(0.0, 2.5 * self.mean[i]);
            counts[i] = self.level[i].round() as u32;
        }
        Availability::new(counts)
    }

    /// Generate a 24-hour series at the given tick interval.
    pub fn series(&mut self, ticks: usize) -> Vec<Availability> {
        (0..ticks).map(|_| self.step()).collect()
    }
}

/// Per-type rental prices in $/h, indexed by `GpuType::index()`. The static
/// Table 1 prices are the [`PriceBook::base`]; the market event stream
/// evolves multipliers on top of them (Vast.ai-style repricing).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PriceBook {
    pub per_hour: [f64; 6],
}

impl PriceBook {
    /// Table 1 list prices.
    pub fn base() -> Self {
        let mut per_hour = [0.0f64; 6];
        for &g in &GpuType::ALL {
            per_hour[g.index()] = GpuSpec::of(g).price_per_hour;
        }
        Self { per_hour }
    }

    pub fn of(&self, gpu: GpuType) -> f64 {
        self.per_hour[gpu.index()]
    }

    /// Hourly price of a composition (GPU counts per type).
    pub fn composition_cost(&self, counts: &[u32]) -> f64 {
        counts
            .iter()
            .zip(&self.per_hour)
            .map(|(&c, &p)| c as f64 * p)
            .sum()
    }

    /// Aggregate relative price deviation from the static Table 1 base
    /// book (mean of |p/p_base − 1| across types). Diagnostic only — the
    /// replanner's drift metric
    /// ([`crate::orchestrator::market_drift`]) measures prices against
    /// the incumbent's *basis* book, not this static base.
    pub fn deviation_from_base(&self) -> f64 {
        let base = Self::base();
        GpuType::ALL
            .iter()
            .map(|&g| (self.of(g) / base.of(g) - 1.0).abs())
            .sum::<f64>()
            / 6.0
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            GpuType::ALL
                .iter()
                .map(|&g| (g.name().to_string(), Json::Num(self.of(g))))
                .collect(),
        )
    }
}

/// What changed in this market tick (coarse classification used by the
/// orchestrator's logging and by strategy escalation heuristics).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MarketEventKind {
    /// Ordinary mean-reverting drift.
    Drift,
    /// Spot-style preemption: a type's pool collapsed (lost ≥ half of the
    /// previous count and at least 4 GPUs).
    Preemption { gpu: GpuType, lost: u32 },
    /// A sudden price spike on one type.
    PriceSpike { gpu: GpuType, factor: f64 },
}

/// One timestamped market observation: the availability snapshot and the
/// price book in force from `t_s` until the next event.
#[derive(Clone, Debug)]
pub struct MarketEvent {
    /// Simulated time of the observation, seconds from stream start.
    pub t_s: f64,
    pub avail: Availability,
    pub prices: PriceBook,
    pub kind: MarketEventKind,
}

/// Iterator of [`MarketEvent`]s: evolves availability through [`MarketSim`]
/// and prices through a mean-reverting multiplier walk with occasional
/// spikes. Fully deterministic from the seed — every orchestrator bench and
/// test replays the exact same market.
#[derive(Clone, Debug)]
pub struct MarketEventStream {
    sim: MarketSim,
    price_rng: Xoshiro256,
    /// Price multiplier per type over the Table 1 base.
    multipliers: [f64; 6],
    /// Probability of a price spike per type per tick.
    spike_prob: f64,
    tick_s: f64,
    t_s: f64,
    remaining: usize,
    prev: Option<Availability>,
}

impl MarketEventStream {
    /// `ticks` events at `tick_s`-second spacing (e.g. 96 × 900 s = 24 h of
    /// 15-minute ticks), first event at t = 0.
    pub fn new(seed: u64, ticks: usize, tick_s: f64) -> Self {
        Self {
            sim: MarketSim::default_market(seed),
            price_rng: Xoshiro256::seed_from_u64(seed ^ 0x9A1C_E5EE),
            multipliers: [1.0; 6],
            spike_prob: 0.03,
            tick_s,
            t_s: 0.0,
            remaining: ticks,
            prev: None,
        }
    }

    /// Total simulated horizon covered by the remaining events, seconds.
    pub fn horizon_s(&self) -> f64 {
        self.remaining as f64 * self.tick_s
    }
}

impl Iterator for MarketEventStream {
    type Item = MarketEvent;

    fn next(&mut self) -> Option<MarketEvent> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let avail = self.sim.step();

        // Price walk: spike with small probability, otherwise mean-revert
        // toward the list price with mild noise.
        let mut spiked: Option<(GpuType, f64)> = None;
        for i in 0..6 {
            if self.price_rng.bernoulli(self.spike_prob) {
                let factor = self.price_rng.range_f64(1.5, 3.0);
                let before = self.multipliers[i];
                self.multipliers[i] = (before * factor).min(4.0);
                // Report the factor actually applied (the 4.0 ceiling can
                // clip the drawn one).
                let applied = self.multipliers[i] / before;
                if spiked.is_none() && applied > 1.0 + 1e-9 {
                    spiked = Some((GpuType::ALL[i], applied));
                }
            } else {
                let noise = 0.03 * self.price_rng.normal();
                self.multipliers[i] += 0.25 * (1.0 - self.multipliers[i]) + noise;
                self.multipliers[i] = self.multipliers[i].clamp(0.5, 4.0);
            }
        }
        let mut prices = PriceBook::base();
        for i in 0..6 {
            prices.per_hour[i] *= self.multipliers[i];
        }

        // Classify: the largest pool collapse wins, then price spikes.
        let mut kind = MarketEventKind::Drift;
        if let Some(prev) = self.prev {
            let mut worst: Option<(GpuType, u32)> = None;
            for &g in &GpuType::ALL {
                let before = prev.of(g);
                let now = avail.of(g);
                let lost = before.saturating_sub(now);
                if lost * 2 >= before && lost >= 4 && worst.map(|(_, l)| lost > l).unwrap_or(true)
                {
                    worst = Some((g, lost));
                }
            }
            if let Some((gpu, lost)) = worst {
                kind = MarketEventKind::Preemption { gpu, lost };
            } else if let Some((gpu, factor)) = spiked {
                kind = MarketEventKind::PriceSpike { gpu, factor };
            }
        }
        self.prev = Some(avail);

        let t_s = self.t_s;
        self.t_s += self.tick_s;
        Some(MarketEvent {
            t_s,
            avail,
            prices,
            kind,
        })
    }
}

/// One tick of the unified world signal: the supply channel (a
/// [`MarketEvent`]: availability + prices) paired with the demand channel
/// (a [`DemandSnapshot`]: arrival rate + workload mixture) in force from
/// `t_s()` until the next event. The orchestrator folds these instead of
/// bare market events so plans track *both* sides of the drift.
#[derive(Clone, Debug)]
pub struct WorldEvent {
    pub market: MarketEvent,
    pub demand: DemandSnapshot,
}

impl WorldEvent {
    /// Pair a market observation with whatever the demand channel carries
    /// at that instant (a schedule sample, an estimator snapshot, or a
    /// frozen stationary mix).
    pub fn new(market: MarketEvent, demand: DemandSnapshot) -> WorldEvent {
        WorldEvent { market, demand }
    }

    /// Simulated observation time, seconds from stream start.
    pub fn t_s(&self) -> f64 {
        self.market.t_s
    }
}

/// Pair each market event with the schedule's demand snapshot at that
/// event's timestamp. Deterministic: the market stream is seeded and the
/// schedule is sampled exactly.
pub fn attach_demand(markets: &[MarketEvent], schedule: &MixSchedule) -> Vec<WorldEvent> {
    markets
        .iter()
        .map(|m| WorldEvent {
            demand: schedule.at(m.t_s),
            market: m.clone(),
        })
        .collect()
}

/// Iterator of [`WorldEvent`]s: the seeded [`MarketEventStream`] supply
/// walk zipped with a [`MixSchedule`] demand channel sampled at each tick.
/// Fully deterministic from the seed, like the market stream it wraps.
#[derive(Clone, Debug)]
pub struct WorldEventStream {
    market: MarketEventStream,
    schedule: MixSchedule,
}

impl WorldEventStream {
    /// `ticks` events at `tick_s`-second spacing, first event at t = 0.
    pub fn new(seed: u64, ticks: usize, tick_s: f64, schedule: MixSchedule) -> Self {
        Self {
            market: MarketEventStream::new(seed, ticks, tick_s),
            schedule,
        }
    }

    /// Total simulated horizon covered by the remaining events, seconds.
    pub fn horizon_s(&self) -> f64 {
        self.market.horizon_s()
    }
}

impl Iterator for WorldEventStream {
    type Item = WorldEvent;

    fn next(&mut self) -> Option<WorldEvent> {
        let market = self.market.next()?;
        Some(WorldEvent {
            demand: self.schedule.at(market.t_s),
            market,
        })
    }
}

/// Cost ledger for a rented composition.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RentalCost {
    /// GPUs rented per type.
    pub rented: [u32; 6],
}

impl RentalCost {
    pub fn add(&mut self, gpu: GpuType, n: u32) {
        self.rented[gpu.index()] += n;
    }

    /// Total $/h.
    pub fn per_hour(&self) -> f64 {
        GpuType::ALL
            .iter()
            .map(|&g| self.rented[g.index()] as f64 * GpuSpec::of(g).price_per_hour)
            .sum()
    }

    /// Fits within availability?
    pub fn feasible(&self, avail: &Availability) -> bool {
        GpuType::ALL
            .iter()
            .all(|&g| self.rented[g.index()] <= avail.of(g))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_reordering_correct() {
        // Avail 1 row in the paper: 4090=16, A40=12, A6000=8, L40=12,
        // A100=6, H100=8.
        let a1 = availability(1);
        assert_eq!(a1.of(GpuType::Rtx4090), 16);
        assert_eq!(a1.of(GpuType::A40), 12);
        assert_eq!(a1.of(GpuType::A6000), 8);
        assert_eq!(a1.of(GpuType::L40), 12);
        assert_eq!(a1.of(GpuType::A100), 6);
        assert_eq!(a1.of(GpuType::H100), 8);
        let a3 = availability(3);
        assert_eq!(a3.of(GpuType::A100), 32);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn availability_bounds_checked() {
        availability(5);
    }

    #[test]
    fn full_rental_cost_of_avail1() {
        // 8*0.83 + 12*0.55 + 12*0.83 + 6*1.75 + 8*2.99 + 16*0.53 = 66.10
        let cost = availability(1).full_rental_cost();
        assert!((cost - 66.10).abs() < 1e-9, "cost={cost}");
    }

    #[test]
    fn market_sim_stays_in_bounds_and_fluctuates() {
        let mut m = MarketSim::default_market(7);
        let series = m.series(96); // 24h at 15-min ticks
        assert_eq!(series.len(), 96);
        let a40_series: Vec<u32> = series.iter().map(|a| a.of(GpuType::A40)).collect();
        let min = *a40_series.iter().min().unwrap();
        let max = *a40_series.iter().max().unwrap();
        assert!(max > min, "series should fluctuate");
        assert!(max <= 40, "max={max}");
    }

    #[test]
    fn market_sim_deterministic() {
        let a: Vec<_> = MarketSim::default_market(3).series(10);
        let b: Vec<_> = MarketSim::default_market(3).series(10);
        assert_eq!(a, b);
    }

    #[test]
    fn rental_cost_accounting() {
        let mut r = RentalCost::default();
        r.add(GpuType::H100, 2);
        r.add(GpuType::A40, 4);
        assert!((r.per_hour() - (2.0 * 2.99 + 4.0 * 0.55)).abs() < 1e-12);
        assert!(r.feasible(&availability(1)));
        let mut r2 = RentalCost::default();
        r2.add(GpuType::A100, 7); // only 6 available in Avail 1
        assert!(!r2.feasible(&availability(1)));
    }

    #[test]
    fn only_and_unlimited() {
        let a = Availability::only(GpuType::H100, 20);
        assert_eq!(a.of(GpuType::H100), 20);
        assert_eq!(a.total(), 20);
        assert!(Availability::unlimited().of(GpuType::A40) > 1_000_000);
    }

    #[test]
    fn unlimited_pool_cost_is_explicit_not_sentinel_dollars() {
        // Regression: the sentinel count used to flow straight into
        // full_rental_cost(), yielding ~$4×10⁹/h "budget bounds".
        let u = Availability::unlimited();
        assert!(u.is_unlimited());
        assert!(u.full_rental_cost().is_infinite());
        assert!(u.full_rental_cost_at(&PriceBook::base()).is_infinite());
        // Budget sanity checks must pass budgets through unchanged.
        assert_eq!(u.budget_cap(30.0), 30.0);
        // Finite pools still clip.
        let a = availability(1);
        assert!(!a.is_unlimited());
        assert!((a.budget_cap(1e9) - a.full_rental_cost()).abs() < 1e-9);
        assert_eq!(a.budget_cap(10.0), 10.0);
        // A single sentinel pool is enough to trip the check.
        let mut partial = availability(1);
        partial.set(GpuType::A40, Availability::UNLIMITED);
        assert!(partial.is_unlimited());
        assert!(partial.full_rental_cost().is_infinite());
    }

    #[test]
    fn price_book_base_matches_table1() {
        let p = PriceBook::base();
        assert!((p.of(GpuType::H100) - 2.99).abs() < 1e-12);
        assert!((p.of(GpuType::Rtx4090) - 0.53).abs() < 1e-12);
        // composition_cost agrees with RentalCost::per_hour.
        let mut r = RentalCost::default();
        r.add(GpuType::H100, 2);
        r.add(GpuType::A40, 4);
        assert!((p.composition_cost(&r.rented) - r.per_hour()).abs() < 1e-12);
        assert!(p.deviation_from_base().abs() < 1e-12);
    }

    #[test]
    fn market_event_stream_deterministic_and_timestamped() {
        let a: Vec<MarketEvent> = MarketEventStream::new(7, 20, 900.0).collect();
        let b: Vec<MarketEvent> = MarketEventStream::new(7, 20, 900.0).collect();
        assert_eq!(a.len(), 20);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.avail, y.avail);
            assert_eq!(x.prices, y.prices);
            assert_eq!(x.kind, y.kind);
        }
        for (i, e) in a.iter().enumerate() {
            assert!((e.t_s - i as f64 * 900.0).abs() < 1e-9);
            for &g in &GpuType::ALL {
                let p = e.prices.of(g);
                let base = PriceBook::base().of(g);
                assert!(p >= 0.5 * base - 1e-9 && p <= 4.0 * base + 1e-9, "price {p}");
            }
        }
    }

    #[test]
    fn world_event_stream_zips_market_with_schedule_demand() {
        use crate::workload::TraceMix;
        let schedule = MixSchedule::shift(
            "world-shift",
            (TraceMix::trace1(), 2.0),
            (TraceMix::trace3(), 4.0),
            0.0,
            9.0 * 900.0,
        )
        .expect("valid shift");
        let events: Vec<WorldEvent> =
            WorldEventStream::new(7, 10, 900.0, schedule.clone()).collect();
        assert_eq!(events.len(), 10);
        // Market channel identical to the bare stream under the same seed.
        let markets: Vec<MarketEvent> = MarketEventStream::new(7, 10, 900.0).collect();
        for (w, m) in events.iter().zip(&markets) {
            assert_eq!(w.market.avail, m.avail);
            assert_eq!(w.market.prices, m.prices);
            assert!((w.t_s() - m.t_s).abs() < 1e-9);
            // Demand channel equals the schedule sampled at the tick.
            let want = schedule.at(m.t_s);
            assert_eq!(w.demand, want);
        }
        // The demand channel actually moves across the horizon.
        assert!(events[0].demand.rate_rps < events[9].demand.rate_rps);
        assert!(events[0].demand.mix.total_variation(&events[9].demand.mix) > 0.3);
        // attach_demand agrees with the zipped stream.
        let attached = attach_demand(&markets, &schedule);
        for (a, b) in attached.iter().zip(&events) {
            assert_eq!(a.demand, b.demand);
            assert_eq!(a.market.avail, b.market.avail);
        }
        // Determinism end to end.
        let again: Vec<WorldEvent> =
            WorldEventStream::new(7, 10, 900.0, schedule).collect();
        for (a, b) in events.iter().zip(&again) {
            assert_eq!(a.demand, b.demand);
            assert_eq!(a.market.prices, b.market.prices);
        }
    }

    #[test]
    fn market_event_stream_produces_disruptions() {
        // Over a long horizon the stream must contain preemptions and price
        // spikes — the whole point of the replanning subsystem.
        let events: Vec<MarketEvent> = MarketEventStream::new(11, 400, 900.0).collect();
        let preemptions = events
            .iter()
            .filter(|e| matches!(e.kind, MarketEventKind::Preemption { .. }))
            .count();
        let spikes = events
            .iter()
            .filter(|e| matches!(e.kind, MarketEventKind::PriceSpike { .. }))
            .count();
        assert!(preemptions > 0, "no preemption in 400 ticks");
        assert!(spikes > 0, "no price spike in 400 ticks");
        // Preemption metadata is consistent with the snapshots.
        let mut prev: Option<Availability> = None;
        for e in &events {
            if let MarketEventKind::Preemption { gpu, lost } = e.kind {
                let before = prev.expect("preemption cannot be the first event").of(gpu);
                assert_eq!(before - e.avail.of(gpu), lost);
                assert!(lost >= 4);
            }
            prev = Some(e.avail);
        }
    }
}
