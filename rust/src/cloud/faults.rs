//! Seeded, composable fault injection over the cloud world signal.
//!
//! The market stream already *drifts* (availability walks, price spikes,
//! pool collapses), but drift alone never kills a replica mid-request:
//! nothing in the seed streams models a spot instance being reclaimed with
//! a two-minute warning, a host crashing with no warning at all, or the
//! control plane acting on an availability snapshot that is minutes stale.
//! [`FaultInjector`] layers exactly those three failure classes over a
//! [`WorldEventStream`], all deterministic from one seed:
//!
//! * **correlated preemption bursts with advance notice** — spot-style
//!   reclaims hitting several replicas at once, each announced
//!   [`FaultProfile::notice_s`] seconds before the replica stops;
//! * **zero-notice crash-stops** — a replica vanishes instantly, its batch
//!   and queue (and their KV state) with it;
//! * **stale availability signals** — the supply channel the orchestrator
//!   replans against is delayed by [`FaultProfile::stale_ticks`] ticks, so
//!   plans chase a market that has already moved.
//!
//! The injector has two coupled surfaces sharing the seed. [`FaultInjector::plan`]
//! compiles the episode schedule into a [`FaultPlan`] the simulators
//! ([`crate::sim::engine`], [`crate::sim::timeline`]) execute against their
//! live fleets — victim selection happens there, deterministically, via each
//! episode's [`ReplicaFault::pick`] salt. [`FaultInjector::wrap`] decorates
//! the world-event iterator the *orchestrator* consumes: the same episodes
//! dent the availability pools (so the planner sees the supply it actually
//! has), and the whole availability channel is optionally served stale.

// Determinism-zone lint policy (mirrors pallas-lint rules P001/F001):
// no unwrap() and no bare float ==/!= outside tests; every comparison
// below either uses a tolerance or carries an audited allow.
#![cfg_attr(not(test), warn(clippy::unwrap_used, clippy::float_cmp))]

use super::{Availability, MarketEventKind, WorldEvent};
use crate::catalog::GpuType;
use crate::util::rng::Xoshiro256;
use std::collections::VecDeque;

/// Shape of the injected fault process. Compose presets with the `with_*`
/// builders; `by_name` maps the CLI's `--faults` values.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultProfile {
    /// Mean seconds between fault episodes (exponential inter-arrivals).
    pub mean_gap_s: f64,
    /// Probability an episode is a *correlated burst* (several replicas at
    /// once — same region, same reclaim sweep) rather than a single loss.
    pub burst_prob: f64,
    /// Burst size upper bound; burst victims are drawn from `2..=max_burst`.
    pub max_burst: usize,
    /// Probability an episode arrives with a spot-style advance-notice
    /// window instead of a zero-notice crash-stop.
    pub notice_prob: f64,
    /// Advance-notice window length, seconds.
    pub notice_s: f64,
    /// The availability signal the orchestrator sees is delayed by this
    /// many world-stream ticks (0 = fresh).
    pub stale_ticks: usize,
}

impl FaultProfile {
    /// Spot reclaim storm: frequent correlated bursts, almost always with
    /// the provider's advance notice, and a supply view one tick stale.
    pub fn preemption_storm() -> Self {
        Self {
            mean_gap_s: 600.0,
            burst_prob: 0.6,
            max_burst: 3,
            notice_prob: 0.9,
            notice_s: 120.0,
            stale_ticks: 1,
        }
    }

    /// Hardware crash storm: the same episode rate, but zero notice — the
    /// worst case for in-flight KV state.
    pub fn crash_storm() -> Self {
        Self {
            notice_prob: 0.0,
            notice_s: 0.0,
            stale_ticks: 0,
            ..Self::preemption_storm()
        }
    }

    /// CLI mapping for `--faults`: `storm`/`preempt` → preemption storm,
    /// `crash` → crash storm, `none`/`off` → no injection.
    pub fn by_name(name: &str) -> Option<Option<Self>> {
        match name {
            "none" | "off" => Some(None),
            "storm" | "preempt" => Some(Some(Self::preemption_storm())),
            "crash" => Some(Some(Self::crash_storm())),
            _ => None,
        }
    }

    /// Override the advance-notice window (the CLI's `--notice-s`).
    #[allow(clippy::float_cmp)] // audited: structural-zero / sentinel tests, see inline allows
    pub fn with_notice_s(mut self, notice_s: f64) -> Self {
        self.notice_s = notice_s.max(0.0);
        // pallas-lint: allow(F001, exact 0.0 is the crash-stop sentinel, clamped just above)
        if self.notice_s == 0.0 {
            self.notice_prob = 0.0;
        }
        self
    }

    /// Override the mean gap between episodes.
    pub fn with_mean_gap_s(mut self, gap_s: f64) -> Self {
        self.mean_gap_s = gap_s.max(1.0);
        self
    }
}

/// One compiled fault episode, as the simulators execute it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReplicaFault {
    /// When the episode is announced, seconds from stream start.
    pub t_s: f64,
    /// Advance-notice window: victims keep serving (draining / migrating)
    /// until [`Self::kill_at_s`] and then stop. `0.0` is a crash-stop.
    pub notice_s: f64,
    /// Replicas hit by this episode (1, or a correlated burst).
    pub victims: usize,
    /// Seeded victim-selection salt. The simulator picks victims starting
    /// at `pick % alive` among its currently alive replicas, so selection
    /// is deterministic without the injector knowing the fleet.
    pub pick: u64,
}

impl ReplicaFault {
    /// When the victims stop serving.
    pub fn kill_at_s(&self) -> f64 {
        self.t_s + self.notice_s
    }

    /// Zero-notice crash-stop?
    #[allow(clippy::float_cmp)] // audited: structural-zero / sentinel tests, see inline allows
    pub fn is_crash(&self) -> bool {
        // pallas-lint: allow(F001, exact 0.0 is the crash-stop sentinel set by the builder)
        self.notice_s == 0.0
    }
}

/// The compiled, deterministic fault schedule for one horizon: episodes in
/// time order, ready for the simulators to execute.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<ReplicaFault>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Total replica-loss episodes (not victims) in the plan.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Episodes that are zero-notice crash-stops.
    pub fn crashes(&self) -> usize {
        self.events.iter().filter(|e| e.is_crash()).count()
    }

    /// Total victim slots across every episode.
    pub fn victims(&self) -> usize {
        self.events.iter().map(|e| e.victims).sum()
    }
}

/// Seeded fault source: one seed fixes the episode schedule *and* the
/// world-signal decoration, so a fault scenario replays bit-identically.
#[derive(Clone, Debug)]
pub struct FaultInjector {
    profile: FaultProfile,
    seed: u64,
}

impl FaultInjector {
    pub fn new(profile: FaultProfile, seed: u64) -> Self {
        Self { profile, seed }
    }

    pub fn profile(&self) -> &FaultProfile {
        &self.profile
    }

    /// Compile the episode schedule for `horizon_s` seconds. Deterministic:
    /// same profile + seed + horizon ⇒ the same plan, and a longer horizon
    /// extends a shorter one's prefix unchanged.
    pub fn plan(&self, horizon_s: f64) -> FaultPlan {
        let mut rng = Xoshiro256::substream(self.seed, 0xFA);
        let lambda = 1.0 / self.profile.mean_gap_s;
        let mut events = Vec::new();
        // First episode after one full gap: a storm never kills the fleet
        // at t = 0, before anything has spun up.
        let mut t = rng.exponential(lambda);
        while t < horizon_s {
            let victims = if self.profile.max_burst >= 2 && rng.bernoulli(self.profile.burst_prob)
            {
                2 + rng.next_below(self.profile.max_burst as u64 - 1) as usize
            } else {
                1
            };
            let notice_s = if rng.bernoulli(self.profile.notice_prob) {
                self.profile.notice_s
            } else {
                0.0
            };
            events.push(ReplicaFault {
                t_s: t,
                notice_s,
                victims,
                pick: rng.next_u64(),
            });
            t += rng.exponential(lambda);
        }
        FaultPlan { events }
    }

    /// Decorate a world-event iterator with this injector's signal faults:
    /// episode bursts dent the largest availability pools (the orchestrator
    /// plans against the supply it actually has left), and the availability
    /// channel is served [`FaultProfile::stale_ticks`] ticks late. Demand
    /// and prices pass through untouched. The episodes applied are exactly
    /// the ones [`Self::plan`] compiles for the same horizon.
    pub fn wrap<I>(&self, horizon_s: f64, inner: I) -> FaultedWorldStream<I>
    where
        I: Iterator<Item = WorldEvent>,
    {
        FaultedWorldStream {
            inner,
            plan: self.plan(horizon_s).events,
            next_fault: 0,
            buffer: VecDeque::new(),
            stale_ticks: self.profile.stale_ticks,
        }
    }
}

/// Iterator adapter produced by [`FaultInjector::wrap`].
#[derive(Clone, Debug)]
pub struct FaultedWorldStream<I> {
    inner: I,
    plan: Vec<ReplicaFault>,
    next_fault: usize,
    /// Sliding window of true availability snapshots; the front is the
    /// stale view reported downstream.
    buffer: VecDeque<Availability>,
    stale_ticks: usize,
}

impl<I> Iterator for FaultedWorldStream<I>
where
    I: Iterator<Item = WorldEvent>,
{
    type Item = WorldEvent;

    fn next(&mut self) -> Option<WorldEvent> {
        let mut ev = self.inner.next()?;

        // Episode bursts reclaim capacity: subtract each victim from the
        // currently largest pool — correlated reclaims concentrate where
        // the fleet (and everyone else's) actually rents.
        let mut reclaimed: Option<(GpuType, u32)> = None;
        while self.next_fault < self.plan.len() && self.plan[self.next_fault].t_s <= ev.t_s() {
            let fault = self.plan[self.next_fault];
            self.next_fault += 1;
            for _ in 0..fault.victims {
                let (idx, _) = ev
                    .market
                    .avail
                    .counts
                    .iter()
                    .enumerate()
                    .max_by_key(|&(_, &c)| c)
                    .expect("six pools");
                if ev.market.avail.counts[idx] == 0 {
                    break; // market already empty: nothing left to reclaim
                }
                ev.market.avail.counts[idx] -= 1;
                let g = GpuType::ALL[idx];
                let lost = reclaimed.map(|(_, l)| l).unwrap_or(0) + 1;
                reclaimed = Some((g, lost));
            }
        }
        if let Some((gpu, lost)) = reclaimed {
            if !matches!(ev.market.kind, MarketEventKind::Preemption { .. }) {
                ev.market.kind = MarketEventKind::Preemption { gpu, lost };
            }
        }

        // Staleness: report the availability observed `stale_ticks` ago.
        if self.stale_ticks > 0 {
            self.buffer.push_back(ev.market.avail);
            if self.buffer.len() > self.stale_ticks + 1 {
                self.buffer.pop_front();
            }
            ev.market.avail = *self.buffer.front().expect("just pushed");
        }
        Some(ev)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::WorldEventStream;
    use crate::workload::{MixSchedule, TraceMix};

    fn world(ticks: usize) -> WorldEventStream {
        WorldEventStream::new(7, ticks, 900.0, MixSchedule::constant(TraceMix::trace1(), 3.0))
    }

    #[test]
    fn seeded_fault_plan_replays_identically() {
        let inj = FaultInjector::new(FaultProfile::preemption_storm(), 0xFEED);
        let a = inj.plan(86_400.0);
        let b = FaultInjector::new(FaultProfile::preemption_storm(), 0xFEED).plan(86_400.0);
        assert_eq!(a, b, "same seed must replay the same schedule");
        assert!(!a.is_empty(), "a day-long storm produced no episodes");
        // A longer horizon extends the shorter plan's prefix unchanged.
        let longer = inj.plan(2.0 * 86_400.0);
        assert_eq!(&longer.events[..a.len()], &a.events[..]);
        assert!(longer.len() > a.len());
        // A different seed moves the schedule.
        let other = FaultInjector::new(FaultProfile::preemption_storm(), 0xBEEF).plan(86_400.0);
        assert_ne!(a, other);
        // Wrapped world events replay identically too.
        let w1: Vec<_> = inj.wrap(86_400.0, world(96)).collect();
        let w2: Vec<_> = inj.wrap(86_400.0, world(96)).collect();
        assert_eq!(w1.len(), w2.len());
        for (x, y) in w1.iter().zip(&w2) {
            assert_eq!(x.market.avail, y.market.avail);
            assert_eq!(x.market.kind, y.market.kind);
        }
    }

    #[test]
    fn storm_profiles_shape_the_schedule() {
        let storm = FaultInjector::new(FaultProfile::preemption_storm(), 3).plan(86_400.0);
        assert!(
            storm.events.iter().any(|e| e.notice_s > 0.0),
            "preemption storm never granted notice"
        );
        assert!(
            storm.events.iter().any(|e| e.victims >= 2),
            "no correlated burst in a day-long storm"
        );
        for e in &storm.events {
            assert!(e.t_s > 0.0 && e.t_s < 86_400.0);
            assert!(e.victims >= 1 && e.victims <= 3);
            assert_eq!(e.kill_at_s(), e.t_s + e.notice_s);
        }
        let crash = FaultInjector::new(FaultProfile::crash_storm(), 3).plan(86_400.0);
        assert!(crash.crashes() == crash.len(), "crash storm must be all zero-notice");
        assert!(crash.victims() >= crash.len());
    }

    #[test]
    fn by_name_maps_cli_values() {
        assert_eq!(FaultProfile::by_name("none"), Some(None));
        assert_eq!(
            FaultProfile::by_name("storm"),
            Some(Some(FaultProfile::preemption_storm()))
        );
        assert_eq!(
            FaultProfile::by_name("crash"),
            Some(Some(FaultProfile::crash_storm()))
        );
        assert_eq!(FaultProfile::by_name("tornado"), None);
        let quiet = FaultProfile::preemption_storm().with_notice_s(0.0);
        assert_eq!(quiet.notice_prob, 0.0, "zero notice implies crash-stops");
    }

    #[test]
    fn wrapped_stream_is_stale_and_dented() {
        let profile = FaultProfile {
            stale_ticks: 2,
            ..FaultProfile::preemption_storm()
        };
        let inj = FaultInjector::new(profile, 0xFEED);
        let horizon = 96.0 * 900.0;
        let raw: Vec<_> = world(96).collect();
        let wrapped: Vec<_> = inj.wrap(horizon, world(96)).collect();
        assert_eq!(wrapped.len(), raw.len());
        let plan = inj.plan(horizon);
        // Total capacity reclaimed must show up as a supply deficit vs the
        // raw stream at the final tick's *fresh* counterpart — compare
        // totals over the whole stream instead of tick-by-tick (staleness
        // shifts the series).
        let raw_total: u64 = raw.iter().map(|e| e.market.avail.total() as u64).sum();
        let wrapped_total: u64 = wrapped.iter().map(|e| e.market.avail.total() as u64).sum();
        assert!(
            wrapped_total < raw_total,
            "storm reclaimed nothing: {wrapped_total} vs {raw_total}"
        );
        assert!(!plan.is_empty());
        // Demand and price channels pass through untouched.
        for (w, r) in wrapped.iter().zip(&raw) {
            assert_eq!(w.demand, r.demand);
            assert_eq!(w.market.prices, r.market.prices);
        }
    }
}
