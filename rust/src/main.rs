//! `hetserve` — the leader binary: plan, simulate, profile, and serve.
//!
//! Subcommands:
//!   plan        — compute the cost-optimal serving plan (§4)
//!   simulate    — run a plan through the discrete-event cluster simulator
//!   orchestrate — online replanning over a fluctuating market + timeline sim
//!   compare     — sweep every `Planner` (ours + all baselines) on one problem
//!   serve       — real serving on the PJRT engine (AOT artifacts required)
//!   profile     — print the h_{c,w} throughput table (one-time profiling)
//!   market      — print a Figure 2-style availability series
//!   help        — this text

use hetserve::baselines::{all_planners, homogeneous_plan};
use hetserve::catalog::GpuType;
use hetserve::cloud::faults::{FaultInjector, FaultProfile};
use hetserve::cloud::{availability, MarketEvent, MarketEventKind, MarketEventStream, MarketSim};
use hetserve::coordinator::{serve, synth_requests, AdmissionPolicy, RouterPolicy, ServerOptions};
use hetserve::orchestrator::{OrchestratorOptions, ReplanStrategy};
use hetserve::perf_model::{ModelSpec, PerfModel};
use hetserve::profiler::Profile;
use hetserve::runtime::{default_artifacts_dir, Engine};
use hetserve::sched::binary_search::{BinarySearchOptions, Feasibility};
use hetserve::sched::enumerate::EnumOptions;
use hetserve::sched::planner::{PlanRequest, Planner, PlannerSession};
use hetserve::sched::SchedProblem;
use hetserve::sim::{
    run_closed_loop, run_closed_loop_streamed, simulate_plan, ClosedLoopOptions, DemandMode,
    EngineOptions, SimOptions, StreamedLoopOptions, TimelineOptions,
};
use hetserve::util::bench::{cell, Table};
use hetserve::util::cli::Args;
use hetserve::workload::{
    synthesize_trace, synthesize_trace_schedule, MixSchedule, SynthOptions, TraceMix, WorkloadType,
};

const HELP: &str = "\
hetserve — cost-efficient LLM serving over heterogeneous GPUs

USAGE: hetserve <subcommand> [--options]

  plan        --model 70b --trace trace1 --avail 1 --budget 30 [--exact] [--requests 2000]
  simulate    (plan options) [--seed N]
  orchestrate --model 8b --trace trace1 --budget 30 --epochs 8 --seed 7
              [--strategy static|incremental|full|escalate[:T]]
              [--tick-s 900] [--rate RPS] [--slo SECONDS]
              [--demand oracle|estimated|static] [--demand-drift T]
              [--shift-to TRACE|r1,..,r9] [--rate-end RPS]
              [--shift-start FRAC] [--shift-end FRAC]
              [--engine] [--sim-shards N] [--threads N]
              [--chunk-s SECONDS] [--max-queue N]
              [--faults storm|crash|none] [--fault-seed N] [--notice-s S]
              (--engine streams arrivals through the sharded event
               engine instead of materializing a trace; same seed ⇒
               bit-identical results at any --threads)
              (--faults injects seeded replica failures: 'storm' is
               correlated spot preemptions with advance notice and a
               stale supply signal, 'crash' is zero-notice crash-stops;
               the orchestrator degrades stepwise — repair-only, shed,
               emergency homogeneous — instead of missing plan deadlines)
  compare     (plan options) — ours vs every baseline planner, one table
  serve       --requests 48 --replicas 2 --router jsq|rr [--arrival-rate RPS]
  profile     --model 70b
  market      --ticks 96 --seed 7
  lint        [--root rust/src] [--baseline rust/analysis/baseline.json]
              [--update-baseline] [--lint-verbose]
              (pallas-lint: the in-repo invariant analyzer — determinism
               zones, atomic-ordering discipline, numerical hygiene,
               panic-path ratchet; fails on any violation not frozen in
               the committed baseline. --update-baseline rewrites the
               baseline to current counts; D-rules are never baselined.)

Global options:
  --log error|warn|info|debug|trace   set the stderr log level
  --verbose                           shorthand for --log debug
  --trace-out PATH   enable telemetry and write a Chrome trace-event JSON
                     (view at https://ui.perfetto.dev); also prints the
                     telemetry snapshot (counters/gauges/histograms)
";

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&["exact", "verbose", "engine", "update-baseline", "lint-verbose"]);
    if let Some(level) = args.get("log") {
        hetserve::util::logging::set_level_from_str(level)
            .map_err(|e| anyhow::anyhow!("--log: {e}"))?;
    } else if args.flag("verbose") {
        hetserve::util::logging::set_level_from_str("debug").expect("literal level is valid");
    }
    let trace_out = args.get("trace-out").map(str::to_string);
    if trace_out.is_some() {
        hetserve::telemetry::set_enabled(true);
    }
    let result = match args.subcommand() {
        Some("plan") => cmd_plan(&args, false),
        Some("simulate") => cmd_plan(&args, true),
        Some("orchestrate") => cmd_orchestrate(&args),
        Some("compare") => cmd_compare(&args),
        Some("serve") => cmd_serve(&args),
        Some("profile") => cmd_profile(&args),
        Some("market") => cmd_market(&args),
        Some("lint") => cmd_lint(&args),
        _ => {
            print!("{HELP}");
            Ok(())
        }
    };
    if let Some(path) = trace_out {
        // Export even after a failed run — a trace of a failure is the
        // one you actually want to look at.
        let snap = hetserve::telemetry::snapshot_json().to_string();
        println!("telemetry: {snap}");
        hetserve::telemetry::write_chrome_trace(&path)
            .map_err(|e| anyhow::anyhow!("--trace-out {path}: {e}"))?;
        println!("trace written to {path} (open in https://ui.perfetto.dev)");
    }
    result
}

fn build_problem(args: &Args) -> (ModelSpec, PerfModel, Profile, TraceMix, SchedProblem) {
    let model = ModelSpec::by_name(args.get_or("model", "70b")).expect("unknown --model");
    let perf = PerfModel::default();
    let profile = Profile::build(&model, &perf, &EnumOptions::default());
    let mix = TraceMix::by_name(args.get_or("trace", "trace1")).expect("unknown --trace");
    let avail = availability(args.get_usize("avail", 1));
    let budget = args.get_f64("budget", 30.0);
    let requests = args.get_f64("requests", 2000.0);
    let problem = SchedProblem::from_profile(&profile, &mix, requests, &avail, budget);
    (model, perf, profile, mix, problem)
}

fn search_opts(args: &Args) -> BinarySearchOptions {
    BinarySearchOptions {
        feasibility: if args.flag("exact") {
            Feasibility::Exact
        } else {
            Feasibility::Knapsack
        },
        ..Default::default()
    }
}

fn cmd_plan(args: &Args, run_sim: bool) -> anyhow::Result<()> {
    let (model, perf, _profile, mix, problem) = build_problem(args);
    let opts = search_opts(args);
    let mut planner = PlannerSession::new(opts.clone());
    let report = planner.plan(&PlanRequest::new(&problem));
    let stats = &report.stats;
    let Some(plan) = &report.plan else {
        anyhow::bail!(
            "no feasible plan under these constraints: {}",
            report
                .infeasible
                .expect("infeasible report carries a reason")
        );
    };
    plan.validate(&problem, 1e-4).map_err(|e| anyhow::anyhow!(e))?;
    println!(
        "plan for {} on {} (budget {} $/h): makespan {:.1}s, cost {:.2} $/h  [{} iters, {} LP solves, {:?}]",
        model.name,
        mix.name,
        problem.budget,
        plan.makespan,
        plan.cost(&problem),
        stats.iterations,
        stats.lp_solves,
        stats.elapsed
    );
    let mut t = Table::new("deployment", &["replicas", "config", "cost $/h", "fractions %"]);
    for e in &plan.entries {
        let c = &problem.candidates[e.candidate];
        t.row(vec![
            e.replicas.to_string(),
            c.label.clone(),
            cell(e.replicas as f64 * c.cost),
            e.fractions
                .iter()
                .map(|f| format!("{:.0}", f * 100.0))
                .collect::<Vec<_>>()
                .join(","),
        ]);
    }
    t.print();

    // Reference: the strongest homogeneous baselines.
    for gpu in [GpuType::H100, GpuType::A6000, GpuType::Rtx4090] {
        if let Some(h) = homogeneous_plan(&problem, gpu, &opts) {
            println!(
                "  vs {:<6} homogeneous: makespan {:.1}s  (baseline is {:+.1}% vs ours)",
                gpu.name(),
                h.makespan,
                (h.makespan / plan.makespan - 1.0) * 100.0
            );
        }
    }

    if run_sim {
        let trace = synthesize_trace(
            &mix,
            &SynthOptions {
                num_requests: problem.total_demand() as usize,
                arrival_rate: 0.0,
                length_sigma: 0.2,
                seed: args.get_u64("seed", 42),
            },
        );
        let result = simulate_plan(
            &problem,
            &plan,
            &[model],
            &[trace],
            &perf,
            &SimOptions::default(),
        );
        println!(
            "simulated: makespan {:.1}s, throughput {:.2} req/s, p50 {:.1}s, p90 {:.1}s, util {:.0}%",
            result.makespan,
            result.throughput_rps,
            result.p_latency(50.0),
            result.p_latency(90.0),
            result.mean_utilization * 100.0
        );
    }
    Ok(())
}

/// Sweep the production planner and every baseline over one problem
/// through the uniform `Planner` contract, printing makespan, cost, and
/// solver effort per strategy — including structured infeasibility
/// reasons for strategies that decline the problem.
fn cmd_compare(args: &Args) -> anyhow::Result<()> {
    let (model, _perf, _profile, mix, problem) = build_problem(args);
    let opts = search_opts(args);
    let mut t = Table::new(
        &format!(
            "compare — {} on {} (budget {} $/h)",
            model.name, mix.name, problem.budget
        ),
        &[
            "planner", "makespan s", "cost $/h", "GPUs", "LPs", "pivots", "outcome",
        ],
    );
    let mut ours: Option<f64> = None;
    for planner in all_planners(&opts).iter_mut() {
        let report = planner.plan(&PlanRequest::new(&problem));
        let name = report.provenance.strategy.clone();
        match &report.plan {
            Some(plan) => {
                if name == "bisection" {
                    ours = Some(plan.makespan);
                }
                let vs = if name == "bisection" {
                    "reference".to_string()
                } else {
                    match ours {
                        Some(best) => {
                            format!("{:+.1}% vs ours", (plan.makespan / best - 1.0) * 100.0)
                        }
                        // The production planner found nothing to compare
                        // against (e.g. it is availability-bound while a
                        // counterfactual baseline is not).
                        None => "no reference".to_string(),
                    }
                };
                t.row(vec![
                    name,
                    cell(plan.makespan),
                    cell(plan.cost(&problem)),
                    plan.gpus_used(&problem)
                        .iter()
                        .map(|u| u.to_string())
                        .collect::<Vec<_>>()
                        .join(","),
                    report.stats.lp_solves.to_string(),
                    report.stats.pivots.to_string(),
                    vs,
                ]);
            }
            None => {
                let reason = report
                    .infeasible
                    .expect("infeasible report carries a reason");
                t.row(vec![
                    name,
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    report.stats.lp_solves.to_string(),
                    report.stats.pivots.to_string(),
                    format!("infeasible: {reason}"),
                ]);
            }
        }
    }
    t.print();
    Ok(())
}

/// Parse `--shift-to`: a trace name (`trace3`) or nine comma-separated
/// ratios (renormalised, so FP-rough CLI input is fine).
fn parse_shift_target(args: &Args) -> anyhow::Result<Option<TraceMix>> {
    let Some(spec) = args.get("shift-to") else {
        return Ok(None);
    };
    if let Some(mix) = TraceMix::by_name(spec) {
        return Ok(Some(mix));
    }
    let parts: Vec<&str> = spec.split(',').collect();
    if parts.len() != 9 {
        anyhow::bail!("--shift-to expects a trace name or 9 comma-separated ratios, got '{spec}'");
    }
    let mut arr = [0.0f64; 9];
    for (r, p) in arr.iter_mut().zip(&parts) {
        *r = p
            .trim()
            .parse::<f64>()
            .map_err(|e| anyhow::anyhow!("--shift-to: bad ratio '{p}': {e}"))?;
    }
    Ok(Some(TraceMix::normalized("cli-shift-target", arr)?))
}

fn cmd_orchestrate(args: &Args) -> anyhow::Result<()> {
    let model = ModelSpec::by_name(args.get_or("model", "8b")).expect("unknown --model");
    let perf = PerfModel::default();
    let profile = Profile::build(&model, &perf, &EnumOptions::default());
    let mix = TraceMix::by_name(args.get_or("trace", "trace1")).expect("unknown --trace");
    let budget = args.get_f64("budget", 30.0);
    let epochs = args.epochs(8).max(1);
    let seed = args.seed(7);
    let tick_s = args.get_f64("tick-s", 900.0);
    let rate = args.get_f64("rate", 2.0);
    let rate_end = args.get_f64("rate-end", rate);
    let slo_s = args.get_f64("slo", 120.0);
    let strategy = ReplanStrategy::parse(args.get_or("strategy", "escalate"))
        .map_err(|e| anyhow::anyhow!("--strategy: {e}"))?;
    let mode = DemandMode::by_name(args.get_or("demand", "estimated"))
        .expect("unknown --demand (oracle|estimated|static)");
    let demand_threshold = args.demand_drift(0.15);
    let horizon_s = epochs as f64 * tick_s;

    // --faults storm|crash|none: seeded chaos over the market signal and
    // the simulated fleet (same injector for both, so they agree).
    let faults = match args.get("faults") {
        Some(name) => match FaultProfile::by_name(name) {
            Some(profile) => profile.map(|p| {
                let p = match args.get("notice-s") {
                    Some(_) => p.with_notice_s(args.get_f64("notice-s", p.notice_s)),
                    None => p,
                };
                FaultInjector::new(p, args.get_u64("fault-seed", seed ^ 0xFA))
            }),
            None => anyhow::bail!("--faults: unknown profile '{name}' (storm|crash|none)"),
        },
        None => None,
    };

    // The demand process: stationary, or a mixture/rate shift across the
    // configured window of the horizon.
    let shift_to = parse_shift_target(args)?;
    let schedule = match shift_to {
        None if (rate_end - rate).abs() < 1e-12 => MixSchedule::constant(mix.clone(), rate),
        target => {
            let to_mix = target.unwrap_or_else(|| mix.clone());
            let start = args.get_f64("shift-start", 0.3).clamp(0.0, 1.0);
            let end = args.get_f64("shift-end", 0.7).clamp(start, 1.0);
            MixSchedule::shift(
                &format!("{}-to-{}", mix.name, to_mix.name),
                (mix.clone(), rate),
                (to_mix, rate_end),
                start * horizon_s,
                end * horizon_s,
            )?
        }
    };

    // The market: a deterministic Vast.ai-style event stream; the demand
    // channel is closed-loop (oracle / estimated / frozen per --demand).
    let markets: Vec<MarketEvent> = MarketEventStream::new(seed, epochs, tick_s).collect();
    let base = SchedProblem::from_profile(
        &profile,
        &mix,
        rate * tick_s, // demand per epoch
        &markets[0].avail,
        budget,
    );
    // --engine: the million-request path. Arrivals stream straight into the
    // sharded event engine — no trace is ever materialized.
    if args.flag("engine") {
        let max_queue = match args.get("max-queue") {
            Some(s) => Some(
                s.parse::<usize>()
                    .map_err(|e| anyhow::anyhow!("--max-queue: {e}"))?,
            ),
            None => None,
        };
        let sopts = StreamedLoopOptions {
            orchestrator: OrchestratorOptions {
                strategy: strategy.clone(),
                demand_drift_threshold: demand_threshold,
                ..Default::default()
            },
            engine: EngineOptions {
                seed,
                slo_latency_s: slo_s,
                shards: args.get_usize("sim-shards", 0),
                threads: args.get_usize("threads", 0),
                chunk_s: args.get_f64("chunk-s", 120.0),
                admission: max_queue.map(AdmissionPolicy::capped).unwrap_or_default(),
                ..Default::default()
            },
            mode,
            synth: SynthOptions {
                length_sigma: 0.2,
                seed,
                ..Default::default()
            },
            faults: faults.clone(),
            ..Default::default()
        };
        let r = run_closed_loop_streamed(
            &base, &markets, &schedule, horizon_s, &model, &perf, &sopts,
        )
        .ok_or_else(|| anyhow::anyhow!("no feasible plan for the initial world"))?;
        let engine = &r.engine;
        let mut t = Table::new(
            &format!(
                "orchestrate --engine {} on {} — {} strategy, {} demand, {} shards × {} threads",
                model.name,
                schedule.name,
                sopts.orchestrator.strategy.name(),
                mode.name(),
                engine.shards,
                engine.threads
            ),
            &[
                "epoch", "t", "arrivals", "shed", "done", "SLO %", "p90 s", "rent $", "mix err",
            ],
        );
        for ((e, s), mix_err) in r.report.epochs.iter().zip(&engine.epochs).zip(&r.mix_error) {
            t.row(vec![
                e.index.to_string(),
                format!("{:.0}", s.start_s),
                s.arrivals.to_string(),
                s.shed.to_string(),
                s.completed.to_string(),
                format!("{:.1}", s.slo_attainment * 100.0),
                cell(s.p90_s),
                cell(s.rental_usd),
                cell(*mix_err),
            ]);
        }
        t.print();
        println!(
            "engine: {} streamed, {} completed, {} shed, SLO {:.1}% at {:.0}s, \
             rental {:.2} $, makespan {:.0}s, peak arrival buffer {}, queue peak {}",
            engine.requests_streamed,
            engine.requests_completed,
            engine.requests_shed,
            engine.slo_attainment * 100.0,
            slo_s,
            engine.total_rental_usd,
            engine.makespan,
            engine.peak_arrival_buffer,
            engine.queue_peak
        );
        println!(
            "perf: {:.0} simulated req/s over {:.2}s wall ({} shards, {} threads, \
             {} transitions), fingerprint {:016x}",
            engine.sim_reqs_per_s(),
            engine.wall_s,
            engine.shards,
            engine.threads,
            engine.transitions_applied,
            engine.fingerprint()
        );
        if sopts.faults.is_some() {
            let f = &engine.faults;
            println!(
                "faults: {} episodes ({} crashes), {} replicas killed, {} requeued, \
                 {} migrated ({:.0} KV tokens, {:.3} $), {} dropped; {} degraded epochs",
                f.episodes,
                f.crashes,
                f.replicas_killed,
                f.requeued,
                f.migrated,
                f.migrated_tokens,
                f.migration_usd,
                f.dropped,
                r.report.degraded_epochs
            );
        }
        return Ok(());
    }

    let trace = synthesize_trace_schedule(
        &schedule,
        horizon_s,
        &SynthOptions {
            length_sigma: 0.2,
            seed,
            ..Default::default()
        },
    );

    let opts = ClosedLoopOptions {
        orchestrator: OrchestratorOptions {
            strategy,
            demand_drift_threshold: demand_threshold,
            ..Default::default()
        },
        timeline: TimelineOptions {
            seed,
            slo_latency_s: slo_s,
            ..Default::default()
        },
        mode,
        faults,
        ..Default::default()
    };
    let loop_result = run_closed_loop(&base, &markets, &schedule, &trace, &model, &perf, &opts)
        .ok_or_else(|| anyhow::anyhow!("no feasible plan for the initial world"))?;
    let report = &loop_result.report;
    let result = &loop_result.sim;

    let mut t = Table::new(
        &format!(
            "orchestrate {} on {} — {} strategy, {} demand, {} epochs × {:.0}s",
            model.name,
            schedule.name,
            opts.orchestrator.strategy.name(),
            mode.name(),
            epochs,
            tick_s
        ),
        &[
            "epoch", "t", "event", "sup drift", "dem drift", "mix err", "plan $/h", "migr $",
            "LPs", "pivots", "arrivals", "SLO %", "p90 s", "rent $",
        ],
    );
    for ((e, s), mix_err) in report
        .epochs
        .iter()
        .zip(&result.epochs)
        .zip(&loop_result.mix_error)
    {
        let event = match e.event_kind {
            MarketEventKind::Drift => "drift".to_string(),
            MarketEventKind::Preemption { gpu, lost } => {
                format!("preempt {}x{}", lost, gpu.name())
            }
            MarketEventKind::PriceSpike { gpu, factor } => {
                format!("spike {} x{:.1}", gpu.name(), factor)
            }
        };
        let path = if e.infeasible {
            " (infeasible)"
        } else if !e.replanned {
            " (absorbed)"
        } else if e.escalated {
            " (escalated)"
        } else if e.fast_path {
            " (fast)"
        } else {
            ""
        };
        let rung = if e.degraded != hetserve::orchestrator::DegradedMode::Normal {
            format!(" [{}]", e.degraded.name())
        } else {
            String::new()
        };
        t.row(vec![
            format!("{}{}{}", e.index, path, rung),
            format!("{:.0}", e.start_s),
            event,
            cell(e.supply_drift),
            cell(e.demand_drift),
            cell(*mix_err),
            cell(e.plan.cost(&e.problem)),
            cell(e.migration.dollars),
            e.stats.lp_solves.to_string(),
            e.stats.pivots.to_string(),
            s.arrivals.to_string(),
            format!("{:.1}", s.slo_attainment * 100.0),
            cell(s.p90_s),
            cell(s.rental_usd),
        ]);
    }
    t.print();
    println!(
        "totals: rental {:.2} $, migration {:.2} $, {} replans ({} escalations, {} fast-path), \
         {} plan transitions, {} replica moves, SLO {:.1}% at {:.0}s, \
         mean mix err {:.3}, makespan {:.0}s",
        result.total_rental_usd,
        report.total_migration.dollars,
        report.replans,
        report.escalations,
        report.fast_paths,
        report.transitions,
        result.transitions_applied,
        result.slo_attainment(slo_s) * 100.0,
        slo_s,
        loop_result.mean_mix_error(),
        result.makespan
    );
    if opts.faults.is_some() {
        let f = &result.faults;
        println!(
            "faults: {} episodes ({} crashes), {} replicas killed, {} requeued, \
             {} migrated ({:.0} KV tokens, {:.3} $), {} dropped; {} degraded epochs",
            f.episodes,
            f.crashes,
            f.replicas_killed,
            f.requeued,
            f.migrated,
            f.migrated_tokens,
            f.migration_usd,
            f.dropped,
            report.degraded_epochs
        );
    }
    println!(
        "solver: {} LP solves, {} pivots ({} steepest-edge), {} B&B nodes, \
         warm-start hit rate {:.0}% ({} warm / {} cold, {} basis roots), \
         {} refactorisations, {} eta updates, {:?} total",
        report.solver.lp_solves,
        report.solver.pivots,
        report.solver.dse_pivots,
        report.solver.milp_nodes,
        report.solver.warm_hit_rate() * 100.0,
        report.solver.warm_solves,
        report.solver.cold_solves,
        report.solver.basis_roots,
        report.solver.refactorisations,
        report.solver.eta_updates,
        report.solver.elapsed
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let engine = Engine::load(&default_artifacts_dir())?;
    let n = args.get_usize("requests", 48);
    let mut reqs = synth_requests(n, 0xE2E, &engine.prefill_buckets(), engine.dims().vocab);
    let rate = args.get_f64("arrival-rate", 0.0);
    if rate > 0.0 {
        for (i, r) in reqs.iter_mut().enumerate() {
            r.arrival_offset_s = i as f64 / rate;
        }
    }
    let report = serve(
        &engine,
        reqs,
        &ServerOptions {
            num_replicas: args.get_usize("replicas", 2),
            max_slots: args.get_usize("slots", 4),
            router: match args.get_or("router", "jsq") {
                "rr" => RouterPolicy::RoundRobin,
                _ => RouterPolicy::Jsq,
            },
            seed: args.get_u64("seed", 7),
            respect_arrivals: rate > 0.0,
        },
    )?;
    println!(
        "served {} requests in {:.2}s — {:.2} req/s, {:.0} tok/s, p50 {:.2}s p90 {:.2}s",
        report.completed,
        report.wall_s,
        report.throughput_rps,
        report.tokens_per_s,
        report.latency.latency_percentile(50.0),
        report.latency.latency_percentile(90.0)
    );
    Ok(())
}

fn cmd_profile(args: &Args) -> anyhow::Result<()> {
    let model = ModelSpec::by_name(args.get_or("model", "70b")).expect("unknown --model");
    let perf = PerfModel::default();
    let profile = Profile::build(&model, &perf, &EnumOptions::default());
    let mut headers = vec!["config".to_string(), "cost $/h".to_string()];
    for w in WorkloadType::all() {
        headers.push(w.label());
    }
    let mut t = Table::new(
        &format!("h_(c,w) for {} (req/s)", model.name),
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for c in &profile.configs {
        let mut row = vec![c.label(), cell(c.cost)];
        for w in 0..9 {
            row.push(cell(c.throughput[w]));
        }
        t.row(row);
    }
    t.print();
    Ok(())
}

fn cmd_market(args: &Args) -> anyhow::Result<()> {
    let ticks = args.get_usize("ticks", 96);
    let mut market = MarketSim::default_market(args.get_u64("seed", 7));
    let series = market.series(ticks);
    let mut t = Table::new(
        "24h availability (Figure 2 style)",
        &["tick", "A6000", "A40", "L40", "A100", "H100", "4090"],
    );
    for (i, a) in series.iter().enumerate() {
        if i % 4 == 0 {
            t.row(
                std::iter::once(format!("{:02}:{:02}", i / 4, (i % 4) * 15))
                    .chain(GpuType::ALL.iter().map(|&g| a.of(g).to_string()))
                    .collect(),
            );
        }
    }
    t.print();
    Ok(())
}

/// `pallas-lint`: run the invariant analyzer over `rust/src` and diff the
/// violations against the committed ratchet baseline. Exits non-zero on
/// any violation the baseline does not freeze — the CI gate.
fn cmd_lint(args: &Args) -> anyhow::Result<()> {
    use hetserve::analysis::{run_lint, LintOptions};
    use std::path::PathBuf;

    // Locate the source tree: honour --root, else probe the two layouts
    // (invoked from the repo root, or from inside rust/).
    let root = match args.get("root") {
        Some(p) => PathBuf::from(p),
        None => ["rust/src", "src"]
            .iter()
            .map(PathBuf::from)
            .find(|p| p.join("lib.rs").is_file())
            .ok_or_else(|| {
                anyhow::anyhow!("cannot locate rust/src (run from the repo root or pass --root)")
            })?,
    };
    let baseline = match args.get("baseline") {
        Some(p) => PathBuf::from(p),
        None => {
            // rust/src -> rust/analysis/baseline.json, next to the tree.
            let parent = root
                .parent()
                .ok_or_else(|| anyhow::anyhow!("--root has no parent directory"))?;
            parent.join("analysis").join("baseline.json")
        }
    };
    let opts = LintOptions {
        update_baseline: args.flag("update-baseline"),
        verbose: args.flag("lint-verbose"),
    };
    let run = run_lint(&root, &baseline, &opts)?;
    print!("{}", run.report);
    if run.failed {
        anyhow::bail!(
            "pallas-lint found new violations (fix them, add a reasoned \
             `// pallas-lint: allow(RULE, reason)`, or — for ratchetable rules \
             only — rerun with --update-baseline)"
        );
    }
    Ok(())
}
