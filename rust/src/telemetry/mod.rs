//! Unified telemetry: metric registry, RAII spans, Chrome-trace export.
//!
//! Every layer of the stack (simplex arena → branch & bound → planner →
//! orchestrator → simulator) reports into one process-global substrate
//! instead of growing its own counters:
//!
//! * **Registry** — [`Counter`] / [`Gauge`] / [`Histogram`] handles interned
//!   by static name ([`counter`], [`gauge`], [`histogram`]). Handles are
//!   `&'static`: look one up once, then every update is a single relaxed
//!   atomic op — cheap enough to sit next to the simplex pivot loop.
//! * **Spans** — [`span`] returns an RAII guard that records a begin/end
//!   event pair into a thread-local buffer; nesting falls out of the
//!   begin/end ordering per thread (Chrome trace `B`/`E` semantics).
//!   Buffers flush into the shared [`drain_events`] sink when the
//!   outermost span of a thread closes, on an explicit [`flush_thread`],
//!   and after every `util::threadpool` job.
//! * **Export** — [`write_chrome_trace`] emits the Chrome trace-event JSON
//!   format (open in <https://ui.perfetto.dev>), one event per line;
//!   [`snapshot`] summarises the registry into a [`TelemetrySnapshot`]
//!   merged into `orchestrate`/`simulate`/`compare` output.
//!
//! The whole subsystem is gated on a process-global flag ([`set_enabled`]):
//! when disabled — the default — every entry point is a single relaxed
//! atomic load and an early return, the same discipline as
//! [`crate::util::logging::enabled`]. See `README.md` in this directory for
//! the event model and the overhead budget.

use crate::util::json::Json;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

// ---- global gate and clock --------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Whether telemetry is collecting. One relaxed load; callers on hot paths
/// check this before doing any per-event work.
#[inline]
pub fn enabled() -> bool {
    // ordering: advisory flag — a stale read only delays when collection
    // starts/stops by one event; no data is published through it
    ENABLED.load(Ordering::Relaxed)
}

/// Turn collection on or off process-wide. Pins the trace clock epoch on
/// first enable so event timestamps start near zero.
pub fn set_enabled(on: bool) {
    if on {
        EPOCH.get_or_init(Instant::now);
    }
    // ordering: see `enabled()` — the flag synchronises nothing; EPOCH's
    // OnceLock provides the only edge (the clock init) that matters here
    ENABLED.store(on, Ordering::Relaxed);
}

/// Microseconds since the trace epoch (pinned at first use).
#[inline]
fn now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

// ---- metric kinds -----------------------------------------------------------

/// Monotonic atomic counter. Updates are relaxed `fetch_add`s, gated on the
/// global enable flag.
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    #[inline]
    pub fn add(&self, n: u64) {
        if enabled() {
            // ordering: pure monotonic tally; totals are read after quiesce
            self.value.fetch_add(n, Ordering::Relaxed);
        }
    }

    #[inline]
    pub fn incr(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        // ordering: report-side read; mid-run snapshots tolerate staleness
        self.value.load(Ordering::Relaxed)
    }

    fn reset(&self) {
        // ordering: reset runs between workloads, never racing recorders
        self.value.store(0, Ordering::Relaxed);
    }
}

/// Last-value gauge storing an `f64` as atomic bits. Reads NaN until first
/// set (NaN serialises as JSON `null`).
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    #[inline]
    pub fn set(&self, v: f64) {
        if enabled() {
            // ordering: last-writer-wins cell; the bits carry the whole value
            self.bits.store(v.to_bits(), Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> f64 {
        // ordering: report-side read of a self-contained value
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }

    fn reset(&self) {
        // ordering: reset runs between workloads, never racing recorders
        self.bits.store(f64::NAN.to_bits(), Ordering::Relaxed);
    }
}

/// Log-bucketed atomic histogram: the lock-free sibling of
/// [`crate::util::stats::LogHistogram`], with identical bucket semantics
/// (value on a boundary falls into the bucket above it; out-of-range values
/// land in the underflow/overflow buckets).
pub struct Histogram {
    /// `n + 1` log-spaced boundaries over `[lo, hi]`.
    bounds: Vec<f64>,
    /// `n + 2` buckets: `[underflow, b0..b1, ..., overflow]`.
    counts: Vec<AtomicU64>,
    total: AtomicU64,
    sum_bits: AtomicU64,
}

impl Histogram {
    fn new(lo: f64, hi: f64, n: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && n >= 2);
        let ratio = (hi / lo).powf(1.0 / n as f64);
        let mut bounds = Vec::with_capacity(n + 1);
        let mut b = lo;
        for _ in 0..=n {
            bounds.push(b);
            b *= ratio;
        }
        let len = bounds.len();
        Self {
            bounds,
            counts: (0..len + 1).map(|_| AtomicU64::new(0)).collect(),
            total: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0.0f64.to_bits()),
        }
    }

    #[inline]
    pub fn record(&self, x: f64) {
        if !enabled() {
            return;
        }
        let idx = match self
            .bounds
            .binary_search_by(|b| b.partial_cmp(&x).expect("histogram sample is NaN"))
        {
            Ok(i) => i + 1,
            Err(i) => i,
        };
        // ordering: independent monotonic tallies; readers (snapshot/report)
        // run after the workload quiesces, so no publication edge is needed
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        // ordering: racing read of the running sum; the CAS below detects
        // interference, so a stale value here only costs a retry
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + x).to_bits();
            // ordering: the CAS carries no payload beyond the compared bits
            match self
                .sum_bits
                .compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
    }

    pub fn count(&self) -> u64 {
        // ordering: report-side read; staleness acceptable mid-run
        self.total.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            f64::NAN
        } else {
            // ordering: report-side read; sum/count may be one event apart
            f64::from_bits(self.sum_bits.load(Ordering::Relaxed)) / n as f64
        }
    }

    /// Raw bucket count (index 0 is underflow, last is overflow) — exposed
    /// for boundary tests.
    pub fn bucket_count(&self, idx: usize) -> u64 {
        // ordering: report-side read; staleness acceptable mid-run
        self.counts[idx].load(Ordering::Relaxed)
    }

    /// Number of buckets including underflow/overflow.
    pub fn num_buckets(&self) -> usize {
        self.counts.len()
    }

    /// The log-spaced bucket boundaries (length `num_buckets() - 1`).
    pub fn boundaries(&self) -> &[f64] {
        &self.bounds
    }

    /// Approximate quantile (returns a bucket boundary), `q` in `[0, 1]`.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return f64::NAN;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            // ordering: report-side read; quantiles are approximate anyway
            acc += c.load(Ordering::Relaxed);
            if acc >= target {
                return if i == 0 {
                    self.bounds[0]
                } else if i > self.bounds.len() - 1 {
                    *self.bounds.last().expect("histogram has >= 2 boundaries")
                } else {
                    self.bounds[i]
                };
            }
        }
        *self.bounds.last().expect("histogram has >= 2 boundaries")
    }

    fn reset(&self) {
        // ordering: reset runs between workloads, never racing recorders
        for c in &self.counts {
            c.store(0, Ordering::Relaxed);
        }
        // ordering: same quiesced-reset contract as the bucket counts
        self.total.store(0, Ordering::Relaxed);
        self.sum_bits.store(0.0f64.to_bits(), Ordering::Relaxed);
    }
}

// ---- registry ---------------------------------------------------------------

#[derive(Default)]
struct Registry {
    counters: Mutex<BTreeMap<&'static str, &'static Counter>>,
    gauges: Mutex<BTreeMap<&'static str, &'static Gauge>>,
    histograms: Mutex<BTreeMap<&'static str, &'static Histogram>>,
}

fn registry() -> &'static Registry {
    static R: OnceLock<Registry> = OnceLock::new();
    R.get_or_init(Registry::default)
}

/// Intern the counter registered under `name`. The handle is `&'static`
/// (one leaked allocation per distinct static name — a bounded set): look
/// it up once outside a loop, then `add` is a single relaxed atomic op.
pub fn counter(name: &'static str) -> &'static Counter {
    let mut map = registry()
        .counters
        .lock()
        .expect("telemetry registry mutex poisoned");
    *map.entry(name).or_insert_with(|| {
        Box::leak(Box::new(Counter {
            value: AtomicU64::new(0),
        }))
    })
}

/// Intern the gauge registered under `name`.
pub fn gauge(name: &'static str) -> &'static Gauge {
    let mut map = registry()
        .gauges
        .lock()
        .expect("telemetry registry mutex poisoned");
    *map.entry(name).or_insert_with(|| {
        Box::leak(Box::new(Gauge {
            bits: AtomicU64::new(f64::NAN.to_bits()),
        }))
    })
}

/// Intern the histogram registered under `name`, log-bucketed over
/// `[lo, hi]` with `n` buckets. Bucket parameters are fixed by the first
/// registration; later calls with different parameters get the original.
pub fn histogram(name: &'static str, lo: f64, hi: f64, n: usize) -> &'static Histogram {
    let mut map = registry()
        .histograms
        .lock()
        .expect("telemetry registry mutex poisoned");
    *map.entry(name)
        .or_insert_with(|| Box::leak(Box::new(Histogram::new(lo, hi, n))))
}

/// Convenience: bump a counter by name. Early-returns (one atomic load)
/// when telemetry is disabled, before touching the registry lock.
#[inline]
pub fn count(name: &'static str, n: u64) {
    if enabled() {
        counter(name).add(n);
    }
}

/// Convenience: set a gauge by name (same gating as [`count`]).
#[inline]
pub fn gauge_set(name: &'static str, v: f64) {
    if enabled() {
        gauge(name).set(v);
    }
}

/// Convenience: record into a default-ranged histogram (`1e-3 .. 1e5`, 64
/// buckets — sized for millisecond-scale durations).
#[inline]
pub fn observe(name: &'static str, x: f64) {
    if enabled() {
        histogram(name, 1e-3, 1e5, 64).record(x);
    }
}

/// Zero every registered metric and clear buffered/flushed trace events
/// (current thread + shared sink). For benches and tests; call between
/// runs, not while spans are open.
pub fn reset() {
    let r = registry();
    let poisoned = "telemetry registry mutex poisoned";
    for c in r.counters.lock().expect(poisoned).values() {
        c.reset();
    }
    for g in r.gauges.lock().expect(poisoned).values() {
        g.reset();
    }
    for h in r.histograms.lock().expect(poisoned).values() {
        h.reset();
    }
    LOCAL.with(|l| l.borrow_mut().events.clear());
    sink().lock().expect("trace sink mutex poisoned").clear();
}

// ---- spans and trace events -------------------------------------------------

/// A tag value attached to a span (emitted into the Chrome event `args`).
#[derive(Clone, Debug)]
pub enum ArgValue {
    Num(f64),
    Str(String),
    Bool(bool),
}

impl From<f64> for ArgValue {
    fn from(x: f64) -> Self {
        ArgValue::Num(x)
    }
}
impl From<u64> for ArgValue {
    fn from(x: u64) -> Self {
        ArgValue::Num(x as f64)
    }
}
impl From<usize> for ArgValue {
    fn from(x: usize) -> Self {
        ArgValue::Num(x as f64)
    }
}
impl From<bool> for ArgValue {
    fn from(x: bool) -> Self {
        ArgValue::Bool(x)
    }
}
impl From<&str> for ArgValue {
    fn from(x: &str) -> Self {
        ArgValue::Str(x.to_string())
    }
}
impl From<String> for ArgValue {
    fn from(x: String) -> Self {
        ArgValue::Str(x)
    }
}

/// One Chrome trace event: a begin (`ph == 'B'`) or end (`ph == 'E'`) of a
/// span, on one thread. Nesting is implied by per-thread B/E ordering.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub name: &'static str,
    pub cat: &'static str,
    pub ph: char,
    pub ts_us: u64,
    pub tid: u64,
    pub args: Vec<(&'static str, ArgValue)>,
}

struct LocalBuf {
    tid: u64,
    depth: u32,
    events: Vec<TraceEvent>,
}

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static LOCAL: RefCell<LocalBuf> = RefCell::new(LocalBuf {
        // ordering: only uniqueness of the handed-out id matters, which
        // fetch_add guarantees at any ordering strength
        tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
        depth: 0,
        events: Vec::new(),
    });
}

fn sink() -> &'static Mutex<Vec<TraceEvent>> {
    static SINK: Mutex<Vec<TraceEvent>> = Mutex::new(Vec::new());
    &SINK
}

/// RAII span guard: created by [`span`], records the end event on drop.
/// Inert (field checks only) when telemetry was disabled at creation.
pub struct Span {
    active: bool,
    name: &'static str,
    cat: &'static str,
    args: Vec<(&'static str, ArgValue)>,
}

/// Open a span named `name` in category `cat`. Bind the guard to a named
/// variable (`let _span = ...`) so it lives to the end of the scope.
pub fn span(name: &'static str, cat: &'static str) -> Span {
    if !enabled() {
        return Span {
            active: false,
            name,
            cat,
            args: Vec::new(),
        };
    }
    let ts_us = now_us();
    LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        let tid = l.tid;
        l.depth += 1;
        l.events.push(TraceEvent {
            name,
            cat,
            ph: 'B',
            ts_us,
            tid,
            args: Vec::new(),
        });
    });
    Span {
        active: true,
        name,
        cat,
        args: Vec::new(),
    }
}

impl Span {
    /// Attach a tag; emitted in the end event's `args`.
    pub fn tag(&mut self, key: &'static str, v: impl Into<ArgValue>) {
        if self.active {
            self.args.push((key, v.into()));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        // The end event is emitted even if telemetry was disabled mid-span,
        // so exported traces always contain well-formed B/E pairs.
        let ts_us = now_us();
        let args = std::mem::take(&mut self.args);
        let flush = LOCAL.with(|l| {
            let mut l = l.borrow_mut();
            let tid = l.tid;
            l.events.push(TraceEvent {
                name: self.name,
                cat: self.cat,
                ph: 'E',
                ts_us,
                tid,
                args,
            });
            l.depth = l.depth.saturating_sub(1);
            l.depth == 0
        });
        if flush {
            flush_thread();
        }
    }
}

/// Move the current thread's buffered events into the shared sink. Called
/// automatically when a thread's outermost span closes and after every
/// `util::threadpool` job; threads outside those paths call it explicitly
/// before exiting.
pub fn flush_thread() {
    let drained: Vec<TraceEvent> = LOCAL.with(|l| std::mem::take(&mut l.borrow_mut().events));
    if !drained.is_empty() {
        sink()
            .lock()
            .expect("trace sink mutex poisoned")
            .extend(drained);
    }
}

/// Flush the current thread, then take every event out of the shared sink.
pub fn drain_events() -> Vec<TraceEvent> {
    flush_thread();
    std::mem::take(&mut *sink().lock().expect("trace sink mutex poisoned"))
}

/// Flush the current thread, then copy the shared sink without draining it
/// (for tests that must not steal events from a concurrent exporter).
pub fn events_snapshot() -> Vec<TraceEvent> {
    flush_thread();
    sink()
        .lock()
        .expect("trace sink mutex poisoned")
        .clone()
}

// ---- Chrome trace export ----------------------------------------------------

fn event_json(e: &TraceEvent) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![
        ("name", Json::str(e.name)),
        ("cat", Json::str(e.cat)),
        ("ph", Json::Str(e.ph.to_string())),
        ("ts", Json::num(e.ts_us as f64)),
        ("pid", Json::num(1.0)),
        ("tid", Json::num(e.tid as f64)),
    ];
    if !e.args.is_empty() {
        let args: Vec<(&str, Json)> = e
            .args
            .iter()
            .map(|(k, v)| {
                let j = match v {
                    ArgValue::Num(x) => Json::num(*x),
                    ArgValue::Str(s) => Json::str(s),
                    ArgValue::Bool(b) => Json::Bool(*b),
                };
                (*k, j)
            })
            .collect();
        fields.push(("args", Json::obj(args)));
    }
    Json::obj(fields)
}

/// Build the Chrome trace-event JSON document for a set of events.
pub fn chrome_trace(events: &[TraceEvent]) -> Json {
    Json::obj(vec![
        ("traceEvents", Json::arr(events.iter().map(event_json))),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

/// Drain all buffered events and write them to `path` as Chrome trace-event
/// JSON — JSONL-style, one event object per line inside the `traceEvents`
/// array, so the file is both valid JSON and line-greppable. Open it at
/// <https://ui.perfetto.dev> or `chrome://tracing`.
pub fn write_chrome_trace(path: &str) -> std::io::Result<()> {
    let events = drain_events();
    let mut out = String::from("{\"displayTimeUnit\":\"ms\",\n\"traceEvents\":[\n");
    for (i, e) in events.iter().enumerate() {
        out.push_str(&event_json(e).to_string());
        if i + 1 < events.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push_str("]}\n");
    std::fs::write(path, out)
}

// ---- snapshot report --------------------------------------------------------

/// Percentile summary of one registered histogram.
#[derive(Clone, Debug)]
pub struct HistogramSummary {
    pub count: u64,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

/// A point-in-time summary of the registry, merged into command output.
#[derive(Clone, Debug)]
pub struct TelemetrySnapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, f64)>,
    pub histograms: Vec<(String, HistogramSummary)>,
}

impl TelemetrySnapshot {
    pub fn to_json(&self) -> Json {
        let counters: Vec<(&str, Json)> = self
            .counters
            .iter()
            .map(|(k, v)| (k.as_str(), Json::num(*v as f64)))
            .collect();
        let gauges: Vec<(&str, Json)> = self
            .gauges
            .iter()
            .map(|(k, v)| (k.as_str(), Json::num(*v)))
            .collect();
        let hists: Vec<(&str, Json)> = self
            .histograms
            .iter()
            .map(|(k, h)| {
                (
                    k.as_str(),
                    Json::obj(vec![
                        ("count", Json::num(h.count as f64)),
                        ("mean", Json::num(h.mean)),
                        ("p50", Json::num(h.p50)),
                        ("p90", Json::num(h.p90)),
                        ("p99", Json::num(h.p99)),
                    ]),
                )
            })
            .collect();
        Json::obj(vec![
            ("counters", Json::obj(counters)),
            ("gauges", Json::obj(gauges)),
            ("histograms", Json::obj(hists)),
        ])
    }
}

/// Summarise every registered metric.
pub fn snapshot() -> TelemetrySnapshot {
    let r = registry();
    let counters = r
        .counters
        .lock()
        .expect("telemetry registry mutex poisoned")
        .iter()
        .map(|(k, c)| (k.to_string(), c.get()))
        .collect();
    let gauges = r
        .gauges
        .lock()
        .expect("telemetry registry mutex poisoned")
        .iter()
        .map(|(k, g)| (k.to_string(), g.get()))
        .collect();
    let histograms = r
        .histograms
        .lock()
        .expect("telemetry registry mutex poisoned")
        .iter()
        .map(|(k, h)| {
            (
                k.to_string(),
                HistogramSummary {
                    count: h.count(),
                    mean: h.mean(),
                    p50: h.quantile(0.5),
                    p90: h.quantile(0.9),
                    p99: h.quantile(0.99),
                },
            )
        })
        .collect();
    TelemetrySnapshot {
        counters,
        gauges,
        histograms,
    }
}

/// [`snapshot`] serialised to JSON.
pub fn snapshot_json() -> Json {
    snapshot().to_json()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serialises tests that toggle the global enable flag.
    fn test_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        match LOCK.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }

    #[test]
    fn disabled_metrics_do_not_move() {
        let _g = test_lock();
        set_enabled(false);
        let c = counter("test.disabled_counter");
        let before = c.get();
        c.add(5);
        assert_eq!(c.get(), before);
        let h = histogram("test.disabled_hist", 0.1, 100.0, 8);
        let n = h.count();
        h.record(1.0);
        assert_eq!(h.count(), n);
    }

    #[test]
    fn counter_and_gauge_roundtrip() {
        let _g = test_lock();
        set_enabled(true);
        let c = counter("test.ctr");
        c.reset();
        c.add(3);
        c.incr();
        assert_eq!(c.get(), 4);
        let g = gauge("test.gauge");
        g.set(2.5);
        assert_eq!(g.get(), 2.5);
        set_enabled(false);
    }

    #[test]
    fn histogram_matches_loghistogram_semantics() {
        let _g = test_lock();
        set_enabled(true);
        let h = histogram("test.hist_semantics", 1.0, 100.0, 4);
        h.reset();
        // Mirror the same stream into util::stats::LogHistogram and compare
        // quantiles — the two implementations share bucket semantics.
        let mut reference = crate::util::stats::LogHistogram::new(1.0, 100.0, 4);
        for x in [0.5, 1.0, 3.0, 9.0, 30.0, 99.0, 150.0, 7.0, 2.0] {
            h.record(x);
            reference.record(x);
        }
        assert_eq!(h.count(), reference.count());
        for q in [0.1, 0.5, 0.9, 1.0] {
            assert_eq!(h.quantile(q), reference.quantile(q), "q={q}");
        }
        set_enabled(false);
    }

    #[test]
    fn snapshot_reports_registered_metrics() {
        let _g = test_lock();
        set_enabled(true);
        counter("test.snap_ctr").reset();
        counter("test.snap_ctr").add(7);
        gauge_set("test.snap_gauge", 1.25);
        let snap = snapshot();
        let c = snap
            .counters
            .iter()
            .find(|(k, _)| k == "test.snap_ctr")
            .expect("registered counter in snapshot");
        assert_eq!(c.1, 7);
        let j = snap.to_json();
        assert_eq!(j.get("counters").get("test.snap_ctr").as_u64(), Some(7));
        assert_eq!(j.get("gauges").get("test.snap_gauge").as_f64(), Some(1.25));
        set_enabled(false);
    }

    #[test]
    fn spans_emit_balanced_pairs_on_this_thread() {
        let _g = test_lock();
        set_enabled(true);
        flush_thread();
        let tid_here = LOCAL.with(|l| l.borrow().tid);
        {
            let mut outer = span("test.outer", "test");
            outer.tag("k", 1.0);
            let _inner = span("test.inner", "test");
        }
        let events: Vec<TraceEvent> = events_snapshot()
            .into_iter()
            .filter(|e| e.tid == tid_here && e.cat == "test")
            .collect();
        let names: Vec<(&str, char)> = events.iter().map(|e| (e.name, e.ph)).collect();
        assert_eq!(
            names,
            vec![
                ("test.outer", 'B'),
                ("test.inner", 'B'),
                ("test.inner", 'E'),
                ("test.outer", 'E'),
            ]
        );
        set_enabled(false);
        drain_events();
    }

    #[test]
    fn disabled_spans_emit_nothing() {
        let _g = test_lock();
        set_enabled(false);
        flush_thread();
        let n0 = LOCAL.with(|l| l.borrow().events.len());
        {
            let _s = span("test.noop", "test");
        }
        assert_eq!(LOCAL.with(|l| l.borrow().events.len()), n0);
    }

    #[test]
    fn chrome_trace_json_shape() {
        let _g = test_lock();
        set_enabled(true);
        drain_events();
        {
            let mut s = span("test.export", "test");
            s.tag("epoch", 3usize);
            s.tag("rung", "fast_path");
        }
        let events = drain_events();
        set_enabled(false);
        let doc = chrome_trace(&events);
        let text = doc.to_string();
        let parsed = Json::parse(&text).expect("trace serialises to valid JSON");
        let evs = parsed.get("traceEvents").as_arr().expect("traceEvents");
        // Other lib tests may flush their own events concurrently; only
        // assert on the span this test created.
        let ours: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("name").as_str() == Some("test.export"))
            .collect();
        assert_eq!(ours.len(), 2, "one B/E pair for test.export");
        let begin = ours[0];
        assert_eq!(begin.get("ph").as_str(), Some("B"));
        assert!(begin.get("ts").as_f64().is_some());
        assert!(begin.get("tid").as_f64().is_some());
        let end = ours[1];
        assert_eq!(end.get("ph").as_str(), Some("E"));
        assert_eq!(end.get("args").get("rung").as_str(), Some("fast_path"));
        assert_eq!(end.get("args").get("epoch").as_u64(), Some(3));
    }

    #[test]
    fn histogram_bucket_boundaries() {
        let _g = test_lock();
        set_enabled(true);
        let h = histogram("test.hist_bounds", 1.0, 16.0, 4);
        h.reset();
        // 4 log buckets over [1, 16]: boundaries ~[1, 2, 4, 8, 16], plus
        // underflow (index 0) and overflow (index 5). Boundary values are
        // read back from the histogram so the exact-hit cases stay exact
        // regardless of how libm rounds the log spacing.
        assert_eq!(h.num_buckets(), 6);
        let bs: Vec<f64> = h.boundaries().to_vec();
        assert_eq!(bs.len(), 5);
        assert_eq!(bs[0], 1.0, "first boundary is exactly lo");
        h.record(0.5); // below lo → underflow
        h.record(bs[0]); // exactly on lo → first real bucket
        h.record(bs[1]); // on an interior boundary → the bucket above it
        h.record(bs[2] * 0.99); // just under a boundary → bucket below it
        h.record(bs[4]); // exactly on hi → overflow (open top)
        h.record(1e9); // far above hi → overflow
        assert_eq!(h.bucket_count(0), 1, "underflow");
        assert_eq!(h.bucket_count(1), 1, "[b0,b1)");
        assert_eq!(h.bucket_count(2), 2, "[b1,b2)");
        assert_eq!(h.bucket_count(3), 0, "[b2,b3)");
        assert_eq!(h.bucket_count(4), 0, "[b3,b4)");
        assert_eq!(h.bucket_count(5), 2, "overflow");
        assert_eq!(h.count(), 6);
        // Quantiles clamp to the boundary range at the extremes.
        assert_eq!(h.quantile(0.0), bs[0]);
        assert_eq!(h.quantile(1.0), bs[4]);
        set_enabled(false);
    }

    #[test]
    fn histogram_mean_tracks_sum() {
        let _g = test_lock();
        set_enabled(true);
        let h = histogram("test.hist_mean", 0.1, 10.0, 4);
        h.reset();
        assert!(h.mean().is_nan(), "empty histogram mean is NaN");
        for x in [1.0, 2.0, 3.0] {
            h.record(x);
        }
        assert!((h.mean() - 2.0).abs() < 1e-12);
        set_enabled(false);
    }
}
