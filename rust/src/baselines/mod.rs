//! Baseline planners (§5.1 Baselines, Figure 7 HexGen comparison, Figure 8
//! ablations):
//!
//! * **Homogeneous** — a single GPU type with an *unlimited* pool (the
//!   paper's assumption for homogeneous baselines), deployment and workload
//!   assignment still optimised by our scheduler ("we fine-tune the
//!   deployment configurations and workload assignments using our
//!   scheduling algorithm to optimize the performance of each homogeneous
//!   baseline");
//! * **HexGen-like** — a *fixed* GPU composition (uniform across types
//!   within budget, or a composition supplied by our planner), deployment
//!   optimised within it, but workload assignment *not* workload-aware:
//!   requests are spread proportionally to aggregate replica rates;
//! * **Ablations** — disable exactly one of the three optimisations:
//!   uniform composition, uniform deployment (TP-only, one global degree),
//!   round-robin workload assignment.

use crate::catalog::{GpuSpec, GpuType};
use crate::cloud::Availability;
use crate::sched::binary_search::{solve_binary_search, BinarySearchOptions};
use crate::sched::{PlanEntry, SchedProblem, ServingPlan};

/// Restrict a problem's candidates to one GPU type and lift availability
/// (the paper's homogeneous setting), then run the full scheduler.
pub fn homogeneous_plan(
    p: &SchedProblem,
    gpu: GpuType,
    opts: &BinarySearchOptions,
) -> Option<ServingPlan> {
    let mut hp = p.clone();
    hp.avail = Availability::unlimited().counts.to_vec();
    let keep: Vec<bool> = p
        .candidates
        .iter()
        .map(|c| {
            c.gpu_counts
                .iter()
                .enumerate()
                .all(|(n, &d)| d == 0 || n == gpu.index())
                && c.gpu_counts[gpu.index()] > 0
        })
        .collect();
    hp.candidates = filter_candidates(&hp, &keep);
    if hp.candidates.is_empty() {
        return None;
    }
    let (plan, _) = solve_binary_search(&hp, opts);
    plan.map(|pl| remap_plan(pl, &keep, p))
}

/// The uniform GPU composition of Figure 7/8: rent GPUs evenly across all
/// six types until the budget is exhausted (whole rounds of one-of-each,
/// then partial rounds in Table-1 order), clipped by availability.
pub fn uniform_composition(budget: f64, avail: &Availability) -> [u32; 6] {
    let mut counts = [0u32; 6];
    let mut cost = 0.0;
    loop {
        let mut progressed = false;
        for &g in &GpuType::ALL {
            let price = GpuSpec::of(g).price_per_hour;
            if counts[g.index()] < avail.of(g) && cost + price <= budget {
                counts[g.index()] += 1;
                cost += price;
                progressed = true;
            }
        }
        if !progressed {
            return counts;
        }
    }
}

/// HexGen-like baseline: fixed composition; deployment optimised within it
/// (our scheduler restricted to the composition); workload assignment
/// replaced with rate-proportional spreading (HexGen is "unaware of the
/// workload heterogeneity, and only consider uniform workload assignment").
pub fn hexgen_plan(
    p: &SchedProblem,
    composition: &[u32; 6],
    opts: &BinarySearchOptions,
) -> Option<ServingPlan> {
    let mut hp = p.clone();
    hp.avail = composition.to_vec();
    // Budget is already spent on the composition: the scheduler may use all
    // of it (cost bounded by the composition's rental price).
    hp.budget = composition
        .iter()
        .enumerate()
        .map(|(n, &k)| k as f64 * GpuSpec::of(GpuType::ALL[n]).price_per_hour)
        .sum::<f64>()
        + 1e-9;
    let (plan, _) = solve_binary_search(&hp, opts)
        ;
    let plan = plan?;
    // Replace the workload-aware fractions with rate-proportional ones.
    Some(rate_proportional_assignment(&hp, plan))
}

/// Re-assign workload fractions proportionally to each entry's aggregate
/// throughput (workload-oblivious spreading).
pub fn rate_proportional_assignment(p: &SchedProblem, plan: ServingPlan) -> ServingPlan {
    let mut entries = plan.entries;
    let nw = p.demands.iter().map(|d| d.len()).max().unwrap_or(0);
    for m in 0..p.demands.len() {
        for w in 0..nw {
            if p.demands[m].get(w).copied().unwrap_or(0.0) <= 0.0 {
                continue;
            }
            // Total rate for (m, w) across active entries.
            let total: f64 = entries
                .iter()
                .filter(|e| p.candidates[e.candidate].model == m)
                .map(|e| e.replicas as f64 * p.candidates[e.candidate].h[w])
                .sum();
            if total <= 0.0 {
                continue;
            }
            for e in entries.iter_mut() {
                let c = &p.candidates[e.candidate];
                if c.model == m {
                    e.fractions[w] = e.replicas as f64 * c.h[w] / total;
                }
            }
        }
    }
    let mut out = ServingPlan {
        entries,
        makespan: 0.0,
    };
    out.makespan = out.evaluate_makespan(p);
    out
}

/// Ablation (i): uniform GPU composition, everything else optimised.
pub fn ablation_uniform_composition(
    p: &SchedProblem,
    opts: &BinarySearchOptions,
) -> Option<ServingPlan> {
    let avail = Availability::new(uniform_composition(
        p.budget,
        &Availability::new([
            p.avail[0], p.avail[1], p.avail[2], p.avail[3], p.avail[4], p.avail[5],
        ]),
    ));
    let mut hp = p.clone();
    hp.avail = avail.counts.to_vec();
    let (plan, _) = solve_binary_search(&hp, opts);
    plan
}

/// Ablation (ii): uniform deployment configuration — "TP is uniformly
/// applied across all replicas" (Figure 8): every replica is a single-stage
/// full-node TP group (tp = the GPU's node size), regardless of model,
/// workload, or GPU type. No per-replica deployment optimisation.
pub fn ablation_uniform_deployment(
    p: &SchedProblem,
    opts: &BinarySearchOptions,
) -> Option<ServingPlan> {
    let keep: Vec<bool> = p
        .candidates
        .iter()
        .map(|c| match &c.replica {
            Some(r) => {
                r.pp() == 1
                    && r.is_homogeneous()
                    && r.stages[0].tp
                        == GpuSpec::of(r.stages[0].gpu).max_gpus_per_node.min(8)
            }
            None => false,
        })
        .collect();
    if !keep.iter().any(|&k| k) {
        return None;
    }
    let mut hp = p.clone();
    hp.candidates = filter_candidates(&hp, &keep);
    let servable = (0..p.demands.len()).all(|m| hp.candidates.iter().any(|c| c.model == m));
    if !servable {
        return None;
    }
    let (plan, _) = solve_binary_search(&hp, opts);
    plan.map(|pl| remap_plan(pl, &keep, p))
}

/// Ablation (iii): round-robin request assignment — composition and
/// deployment from the full planner, fractions replaced by replica-count-
/// proportional spreading (every replica receives the same request mix).
pub fn ablation_round_robin(
    p: &SchedProblem,
    opts: &BinarySearchOptions,
) -> Option<ServingPlan> {
    let (plan, _) = solve_binary_search(p, opts);
    let plan = plan?;
    let mut entries = plan.entries;
    let nw = p.demands.iter().map(|d| d.len()).max().unwrap_or(0);
    for m in 0..p.demands.len() {
        let total_replicas: u32 = entries
            .iter()
            .filter(|e| p.candidates[e.candidate].model == m)
            .map(|e| e.replicas)
            .sum();
        if total_replicas == 0 {
            continue;
        }
        for w in 0..nw {
            if p.demands[m].get(w).copied().unwrap_or(0.0) <= 0.0 {
                continue;
            }
            for e in entries.iter_mut() {
                let c = &p.candidates[e.candidate];
                if c.model == m {
                    e.fractions[w] = e.replicas as f64 / total_replicas as f64;
                }
            }
        }
    }
    let mut out = ServingPlan {
        entries,
        makespan: 0.0,
    };
    out.makespan = out.evaluate_makespan(p);
    Some(out)
}

// ---- helpers ----------------------------------------------------------------

/// Keep only candidates where keep[i]; the returned candidates are cloned in
/// original order so plan entries can be remapped back by `remap_plan`.
fn filter_candidates(p: &SchedProblem, keep: &[bool]) -> Vec<crate::sched::Candidate> {
    p.candidates
        .iter()
        .zip(keep)
        .filter_map(|(c, &k)| if k { Some(c.clone()) } else { None })
        .collect()
}

/// Remap entry candidate indices from the filtered space back to the
/// original problem's indices.
fn remap_plan(plan: ServingPlan, keep: &[bool], original: &SchedProblem) -> ServingPlan {
    let map: Vec<usize> = keep
        .iter()
        .enumerate()
        .filter_map(|(i, &k)| if k { Some(i) } else { None })
        .collect();
    let entries = plan
        .entries
        .into_iter()
        .map(|mut e| {
            e.candidate = map[e.candidate];
            e
        })
        .collect::<Vec<PlanEntry>>();
    let mut out = ServingPlan {
        entries,
        makespan: 0.0,
    };
    out.makespan = out.evaluate_makespan(original);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::availability;
    use crate::perf_model::{ModelSpec, PerfModel};
    use crate::profiler::Profile;
    use crate::sched::enumerate::EnumOptions;
    use crate::workload::TraceMix;

    fn problem(budget: f64) -> SchedProblem {
        let model = ModelSpec::llama3_70b();
        let perf = PerfModel::default();
        let profile = Profile::build(&model, &perf, &EnumOptions::default());
        SchedProblem::from_profile(
            &profile,
            &TraceMix::trace1(),
            2000.0,
            &availability(1),
            budget,
        )
    }

    fn opts() -> BinarySearchOptions {
        BinarySearchOptions {
            tolerance: 2.0,
            ..Default::default()
        }
    }

    #[test]
    fn ours_beats_every_homogeneous_baseline() {
        // The paper's headline: the heterogeneous plan outperforms H100,
        // A6000, and 4090 homogeneous setups at the same budget.
        let p = problem(30.0);
        let (ours, _) = solve_binary_search(&p, &opts());
        let ours = ours.unwrap();
        for gpu in [GpuType::H100, GpuType::A6000] {
            let homo = homogeneous_plan(&p, gpu, &opts()).unwrap();
            assert!(
                ours.makespan <= homo.makespan * 1.02,
                "ours {} vs {} homo {}",
                ours.makespan,
                gpu.name(),
                homo.makespan
            );
        }
        // 4090 cannot serve 70B at all except via big pipelines; allow None.
        if let Some(r4090) = homogeneous_plan(&p, GpuType::Rtx4090, &opts()) {
            assert!(ours.makespan <= r4090.makespan * 1.02);
        }
    }

    #[test]
    fn uniform_composition_fits_budget_and_avail() {
        let avail = availability(1);
        let comp = uniform_composition(30.0, &avail);
        let cost: f64 = comp
            .iter()
            .enumerate()
            .map(|(n, &k)| k as f64 * GpuSpec::of(GpuType::ALL[n]).price_per_hour)
            .sum();
        assert!(cost <= 30.0 + 1e-9);
        for (n, &k) in comp.iter().enumerate() {
            assert!(k <= avail.counts[n]);
        }
        // Uses multiple types.
        assert!(comp.iter().filter(|&&k| k > 0).count() >= 4);
    }

    #[test]
    fn hexgen_uniform_worse_than_ours() {
        let p = problem(30.0);
        let (ours, _) = solve_binary_search(&p, &opts());
        let ours = ours.unwrap();
        let comp = uniform_composition(30.0, &availability(1));
        let hex = hexgen_plan(&p, &comp, &opts()).unwrap();
        assert!(
            hex.makespan >= ours.makespan * 0.98,
            "hexgen {} vs ours {}",
            hex.makespan,
            ours.makespan
        );
    }

    #[test]
    fn hexgen_with_our_composition_still_loses_to_workload_aware() {
        // Figure 7 second bar: HexGen with the optimal composition still
        // loses because assignment is rate-proportional, not workload-aware.
        let p = problem(30.0);
        let (ours, _) = solve_binary_search(&p, &opts());
        let ours = ours.unwrap();
        let comp_vec = ours.gpus_used(&p);
        let comp = [
            comp_vec[0], comp_vec[1], comp_vec[2], comp_vec[3], comp_vec[4], comp_vec[5],
        ];
        let hex = hexgen_plan(&p, &comp, &opts()).unwrap();
        assert!(
            hex.makespan >= ours.makespan * 0.98,
            "hexgen-opt {} vs ours {}",
            hex.makespan,
            ours.makespan
        );
    }

    #[test]
    fn ablations_degrade_or_match() {
        let p = problem(30.0);
        let (ours, _) = solve_binary_search(&p, &opts());
        let ours = ours.unwrap();
        let cases: Vec<(&str, Option<ServingPlan>)> = vec![
            ("uniform-comp", ablation_uniform_composition(&p, &opts())),
            ("uniform-deploy", ablation_uniform_deployment(&p, &opts())),
            ("round-robin", ablation_round_robin(&p, &opts())),
        ];
        for (name, plan) in cases {
            let plan = plan.unwrap_or_else(|| panic!("{name} produced no plan"));
            assert!(
                plan.makespan >= ours.makespan * 0.95,
                "{name}: {} vs ours {}",
                plan.makespan,
                ours.makespan
            );
        }
    }

    #[test]
    fn round_robin_fractions_sum_to_one() {
        let p = problem(30.0);
        let plan = ablation_round_robin(&p, &opts()).unwrap();
        for w in 0..9 {
            if p.demands[0][w] <= 0.0 {
                continue;
            }
            let cover: f64 = plan.entries.iter().map(|e| e.fractions[w]).sum();
            assert!((cover - 1.0).abs() < 1e-6, "w{w} cover={cover}");
        }
    }
}
