//! Baseline planners (§5.1 Baselines, Figure 7 HexGen comparison, Figure 8
//! ablations), all implementing [`crate::sched::planner::Planner`] so the
//! `compare` CLI, the benches, and the property tests sweep them through
//! the same [`PlanRequest`]/[`PlanReport`] contract as the production
//! planner:
//!
//! * [`HomogeneousPlanner`] — a single GPU type with an *unlimited* pool
//!   (the paper's assumption for homogeneous baselines), deployment and
//!   workload assignment still optimised by our scheduler ("we fine-tune
//!   the deployment configurations and workload assignments using our
//!   scheduling algorithm to optimize the performance of each homogeneous
//!   baseline"). Its plans answer a counterfactual (unlimited supply), so
//!   they deliberately do not validate against the request's availability;
//! * [`HexGenPlanner`] — a *fixed* GPU composition (uniform across types
//!   within budget, or a composition supplied by the caller), deployment
//!   optimised within it, but workload assignment *not* workload-aware:
//!   requests are spread proportionally to aggregate replica rates;
//! * [`AblationPlanner`] — disable exactly one of the three optimisations:
//!   uniform composition, uniform deployment (TP-only, one global degree),
//!   round-robin workload assignment.
//!
//! The pre-redesign free functions ([`homogeneous_plan`], [`hexgen_plan`],
//! [`ablation_uniform_composition`], …) remain as thin wrappers over the
//! planner impls.

use crate::catalog::{GpuSpec, GpuType};
use crate::cloud::Availability;
use crate::sched::binary_search::{BinarySearchOptions, SearchStats};
use crate::sched::planner::{
    plan_once, Infeasibility, PlanReport, PlanRequest, Planner, Provenance,
};
use crate::sched::{PlanEntry, SchedProblem, ServingPlan};

/// Restrict a problem's candidates to one GPU type and lift availability
/// (the paper's homogeneous setting), then run the full scheduler.
pub struct HomogeneousPlanner {
    pub gpu: GpuType,
    pub opts: BinarySearchOptions,
}

impl Planner for HomogeneousPlanner {
    fn name(&self) -> String {
        format!("homogeneous-{}", self.gpu.name())
    }

    fn plan(&mut self, req: &PlanRequest) -> PlanReport {
        let p = req.problem;
        let provenance = Provenance::cold(self.name());
        let mut hp = p.clone();
        hp.avail = Availability::unlimited().counts.to_vec();
        let keep: Vec<bool> = p
            .candidates
            .iter()
            .map(|c| {
                c.gpu_counts
                    .iter()
                    .enumerate()
                    .all(|(n, &d)| d == 0 || n == self.gpu.index())
                    && c.gpu_counts[self.gpu.index()] > 0
            })
            .collect();
        hp.candidates = filter_candidates(&hp, &keep);
        if hp.candidates.is_empty() {
            return PlanReport::not_found(
                Infeasibility::NoCandidates,
                SearchStats::default(),
                provenance,
            );
        }
        let inner = plan_once(&hp, &req.effective_opts(&self.opts));
        match inner.plan {
            Some(plan) => {
                PlanReport::found(remap_plan(plan, &keep, p), inner.stats, provenance)
            }
            None => PlanReport::not_found(
                inner.infeasible.unwrap_or(Infeasibility::Exhausted),
                inner.stats,
                provenance,
            ),
        }
    }
}

/// The uniform GPU composition of Figure 7/8: rent GPUs evenly across all
/// six types until the budget is exhausted (whole rounds of one-of-each,
/// then partial rounds in Table-1 order), clipped by availability.
pub fn uniform_composition(budget: f64, avail: &Availability) -> [u32; 6] {
    let mut counts = [0u32; 6];
    let mut cost = 0.0;
    loop {
        let mut progressed = false;
        for &g in &GpuType::ALL {
            let price = GpuSpec::of(g).price_per_hour;
            if counts[g.index()] < avail.of(g) && cost + price <= budget {
                counts[g.index()] += 1;
                cost += price;
                progressed = true;
            }
        }
        if !progressed {
            return counts;
        }
    }
}

/// HexGen-like baseline: fixed composition; deployment optimised within it
/// (our scheduler restricted to the composition); workload assignment
/// replaced with rate-proportional spreading (HexGen is "unaware of the
/// workload heterogeneity, and only consider uniform workload assignment").
/// With no explicit composition, the Figure-7 uniform one is derived from
/// the request's budget and availability.
pub struct HexGenPlanner {
    /// `None` derives the uniform composition from the request.
    pub composition: Option<[u32; 6]>,
    pub opts: BinarySearchOptions,
}

impl Planner for HexGenPlanner {
    fn name(&self) -> String {
        match self.composition {
            Some(_) => "hexgen-fixed".to_string(),
            None => "hexgen-uniform".to_string(),
        }
    }

    fn plan(&mut self, req: &PlanRequest) -> PlanReport {
        let p = req.problem;
        let provenance = Provenance::cold(self.name());
        if p.num_gpu_types != 6 {
            // Compositions are defined over the 6-type cloud catalog.
            return PlanReport::not_found(
                Infeasibility::NoCandidates,
                SearchStats::default(),
                provenance,
            );
        }
        let composition = self.composition.unwrap_or_else(|| {
            uniform_composition(
                p.budget,
                &Availability::new([
                    p.avail[0], p.avail[1], p.avail[2], p.avail[3], p.avail[4], p.avail[5],
                ]),
            )
        });
        let mut hp = p.clone();
        hp.avail = composition.to_vec();
        // Budget is already spent on the composition: the scheduler may use
        // all of it (cost bounded by the composition's rental price).
        hp.budget = composition
            .iter()
            .enumerate()
            .map(|(n, &k)| k as f64 * GpuSpec::of(GpuType::ALL[n]).price_per_hour)
            .sum::<f64>()
            + 1e-9;
        let inner = plan_once(&hp, &req.effective_opts(&self.opts));
        match inner.plan {
            // Replace the workload-aware fractions with rate-proportional
            // ones.
            Some(plan) => PlanReport::found(
                rate_proportional_assignment(&hp, plan),
                inner.stats,
                provenance,
            ),
            None => PlanReport::not_found(
                inner.infeasible.unwrap_or(Infeasibility::Exhausted),
                inner.stats,
                provenance,
            ),
        }
    }
}

/// Re-assign workload fractions proportionally to each entry's aggregate
/// throughput (workload-oblivious spreading).
pub fn rate_proportional_assignment(p: &SchedProblem, plan: ServingPlan) -> ServingPlan {
    let mut entries = plan.entries;
    let nw = p.demands.iter().map(|d| d.len()).max().unwrap_or(0);
    for m in 0..p.demands.len() {
        for w in 0..nw {
            if p.demands[m].get(w).copied().unwrap_or(0.0) <= 0.0 {
                continue;
            }
            // Total rate for (m, w) across active entries.
            let total: f64 = entries
                .iter()
                .filter(|e| p.candidates[e.candidate].model == m)
                .map(|e| e.replicas as f64 * p.candidates[e.candidate].h[w])
                .sum();
            if total <= 0.0 {
                continue;
            }
            for e in entries.iter_mut() {
                let c = &p.candidates[e.candidate];
                if c.model == m {
                    e.fractions[w] = e.replicas as f64 * c.h[w] / total;
                }
            }
        }
    }
    let mut out = ServingPlan {
        entries,
        makespan: 0.0,
    };
    out.makespan = out.evaluate_makespan(p);
    out
}

/// Which single optimisation a Figure-8 ablation disables.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ablation {
    /// (i) uniform GPU composition, everything else optimised.
    UniformComposition,
    /// (ii) uniform deployment configuration — "TP is uniformly applied
    /// across all replicas": every replica is a single-stage full-node TP
    /// group, regardless of model, workload, or GPU type.
    UniformDeployment,
    /// (iii) round-robin request assignment — composition and deployment
    /// from the full planner, fractions replaced by replica-count-
    /// proportional spreading.
    RoundRobin,
}

impl Ablation {
    pub fn label(&self) -> &'static str {
        match self {
            Ablation::UniformComposition => "ablation-uniform-comp",
            Ablation::UniformDeployment => "ablation-uniform-deploy",
            Ablation::RoundRobin => "ablation-round-robin",
        }
    }
}

/// A Figure-8 ablation as a [`Planner`].
pub struct AblationPlanner {
    pub kind: Ablation,
    pub opts: BinarySearchOptions,
}

impl Planner for AblationPlanner {
    fn name(&self) -> String {
        self.kind.label().to_string()
    }

    fn plan(&mut self, req: &PlanRequest) -> PlanReport {
        let p = req.problem;
        let provenance = Provenance::cold(self.name());
        let opts = req.effective_opts(&self.opts);
        let empty = |reason| {
            PlanReport::not_found(reason, SearchStats::default(), Provenance::cold(self.name()))
        };
        match self.kind {
            Ablation::UniformComposition => {
                if p.num_gpu_types != 6 {
                    return empty(Infeasibility::NoCandidates);
                }
                let avail = Availability::new(uniform_composition(
                    p.budget,
                    &Availability::new([
                        p.avail[0], p.avail[1], p.avail[2], p.avail[3], p.avail[4], p.avail[5],
                    ]),
                ));
                let mut hp = p.clone();
                hp.avail = avail.counts.to_vec();
                let inner = plan_once(&hp, &opts);
                PlanReport {
                    provenance,
                    ..inner
                }
            }
            Ablation::UniformDeployment => {
                let keep: Vec<bool> = p
                    .candidates
                    .iter()
                    .map(|c| match &c.replica {
                        Some(r) => {
                            r.pp() == 1
                                && r.is_homogeneous()
                                && r.stages[0].tp
                                    == GpuSpec::of(r.stages[0].gpu).max_gpus_per_node.min(8)
                        }
                        None => false,
                    })
                    .collect();
                if !keep.iter().any(|&k| k) {
                    return empty(Infeasibility::NoCandidates);
                }
                let mut hp = p.clone();
                hp.candidates = filter_candidates(&hp, &keep);
                let servable =
                    (0..p.demands.len()).all(|m| hp.candidates.iter().any(|c| c.model == m));
                if !servable {
                    return empty(Infeasibility::NoCandidates);
                }
                let inner = plan_once(&hp, &opts);
                match inner.plan {
                    Some(plan) => PlanReport::found(
                        remap_plan(plan, &keep, p),
                        inner.stats,
                        provenance,
                    ),
                    None => PlanReport::not_found(
                        inner.infeasible.unwrap_or(Infeasibility::Exhausted),
                        inner.stats,
                        provenance,
                    ),
                }
            }
            Ablation::RoundRobin => {
                let inner = plan_once(p, &opts);
                let Some(plan) = inner.plan else {
                    return PlanReport::not_found(
                        inner.infeasible.unwrap_or(Infeasibility::Exhausted),
                        inner.stats,
                        provenance,
                    );
                };
                let mut entries = plan.entries;
                let nw = p.demands.iter().map(|d| d.len()).max().unwrap_or(0);
                for m in 0..p.demands.len() {
                    let total_replicas: u32 = entries
                        .iter()
                        .filter(|e| p.candidates[e.candidate].model == m)
                        .map(|e| e.replicas)
                        .sum();
                    if total_replicas == 0 {
                        continue;
                    }
                    for w in 0..nw {
                        if p.demands[m].get(w).copied().unwrap_or(0.0) <= 0.0 {
                            continue;
                        }
                        for e in entries.iter_mut() {
                            let c = &p.candidates[e.candidate];
                            if c.model == m {
                                e.fractions[w] =
                                    e.replicas as f64 / total_replicas as f64;
                            }
                        }
                    }
                }
                let mut out = ServingPlan {
                    entries,
                    makespan: 0.0,
                };
                out.makespan = out.evaluate_makespan(p);
                PlanReport::found(out, inner.stats, provenance)
            }
        }
    }
}

/// Every baseline strategy (plus the production bisection) as boxed
/// [`Planner`]s — the `compare` subcommand and the trait-level property
/// test sweep this registry.
pub fn all_planners(opts: &BinarySearchOptions) -> Vec<Box<dyn Planner>> {
    let mut planners: Vec<Box<dyn Planner>> = vec![Box::new(
        crate::sched::planner::BisectionPlanner::new(opts.clone()),
    )];
    for gpu in [GpuType::H100, GpuType::A6000, GpuType::Rtx4090] {
        planners.push(Box::new(HomogeneousPlanner {
            gpu,
            opts: opts.clone(),
        }));
    }
    planners.push(Box::new(HexGenPlanner {
        composition: None,
        opts: opts.clone(),
    }));
    for kind in [
        Ablation::UniformComposition,
        Ablation::UniformDeployment,
        Ablation::RoundRobin,
    ] {
        planners.push(Box::new(AblationPlanner {
            kind,
            opts: opts.clone(),
        }));
    }
    planners
}

// ---- pre-redesign free-function wrappers ------------------------------------

/// Homogeneous baseline as a one-shot call (wrapper over
/// [`HomogeneousPlanner`]).
pub fn homogeneous_plan(
    p: &SchedProblem,
    gpu: GpuType,
    opts: &BinarySearchOptions,
) -> Option<ServingPlan> {
    HomogeneousPlanner {
        gpu,
        opts: opts.clone(),
    }
    .plan(&PlanRequest::new(p))
    .into_plan()
}

/// HexGen-like baseline as a one-shot call (wrapper over
/// [`HexGenPlanner`]).
pub fn hexgen_plan(
    p: &SchedProblem,
    composition: &[u32; 6],
    opts: &BinarySearchOptions,
) -> Option<ServingPlan> {
    HexGenPlanner {
        composition: Some(*composition),
        opts: opts.clone(),
    }
    .plan(&PlanRequest::new(p))
    .into_plan()
}

/// Ablation (i) as a one-shot call (wrapper over [`AblationPlanner`]).
pub fn ablation_uniform_composition(
    p: &SchedProblem,
    opts: &BinarySearchOptions,
) -> Option<ServingPlan> {
    AblationPlanner {
        kind: Ablation::UniformComposition,
        opts: opts.clone(),
    }
    .plan(&PlanRequest::new(p))
    .into_plan()
}

/// Ablation (ii) as a one-shot call (wrapper over [`AblationPlanner`]).
pub fn ablation_uniform_deployment(
    p: &SchedProblem,
    opts: &BinarySearchOptions,
) -> Option<ServingPlan> {
    AblationPlanner {
        kind: Ablation::UniformDeployment,
        opts: opts.clone(),
    }
    .plan(&PlanRequest::new(p))
    .into_plan()
}

/// Ablation (iii) as a one-shot call (wrapper over [`AblationPlanner`]).
pub fn ablation_round_robin(
    p: &SchedProblem,
    opts: &BinarySearchOptions,
) -> Option<ServingPlan> {
    AblationPlanner {
        kind: Ablation::RoundRobin,
        opts: opts.clone(),
    }
    .plan(&PlanRequest::new(p))
    .into_plan()
}

// ---- helpers ----------------------------------------------------------------

/// Keep only candidates where keep[i]; the returned candidates are cloned in
/// original order so plan entries can be remapped back by `remap_plan`.
fn filter_candidates(p: &SchedProblem, keep: &[bool]) -> Vec<crate::sched::Candidate> {
    p.candidates
        .iter()
        .zip(keep)
        .filter_map(|(c, &k)| if k { Some(c.clone()) } else { None })
        .collect()
}

/// Remap entry candidate indices from the filtered space back to the
/// original problem's indices.
fn remap_plan(plan: ServingPlan, keep: &[bool], original: &SchedProblem) -> ServingPlan {
    let map: Vec<usize> = keep
        .iter()
        .enumerate()
        .filter_map(|(i, &k)| if k { Some(i) } else { None })
        .collect();
    let entries = plan
        .entries
        .into_iter()
        .map(|mut e| {
            e.candidate = map[e.candidate];
            e
        })
        .collect::<Vec<PlanEntry>>();
    let mut out = ServingPlan {
        entries,
        makespan: 0.0,
    };
    out.makespan = out.evaluate_makespan(original);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::availability;
    use crate::perf_model::{ModelSpec, PerfModel};
    use crate::profiler::Profile;
    use crate::sched::enumerate::EnumOptions;
    use crate::workload::TraceMix;

    fn problem(budget: f64) -> SchedProblem {
        let model = ModelSpec::llama3_70b();
        let perf = PerfModel::default();
        let profile = Profile::build(&model, &perf, &EnumOptions::default());
        SchedProblem::from_profile(
            &profile,
            &TraceMix::trace1(),
            2000.0,
            &availability(1),
            budget,
        )
    }

    fn opts() -> BinarySearchOptions {
        BinarySearchOptions {
            tolerance: 2.0,
            ..Default::default()
        }
    }

    fn ours(p: &SchedProblem) -> ServingPlan {
        plan_once(p, &opts()).into_plan().expect("our plan")
    }

    #[test]
    fn ours_beats_every_homogeneous_baseline() {
        // The paper's headline: the heterogeneous plan outperforms H100,
        // A6000, and 4090 homogeneous setups at the same budget.
        let p = problem(30.0);
        let ours = ours(&p);
        for gpu in [GpuType::H100, GpuType::A6000] {
            let homo = homogeneous_plan(&p, gpu, &opts()).unwrap();
            assert!(
                ours.makespan <= homo.makespan * 1.02,
                "ours {} vs {} homo {}",
                ours.makespan,
                gpu.name(),
                homo.makespan
            );
        }
        // 4090 cannot serve 70B at all except via big pipelines; allow None.
        if let Some(r4090) = homogeneous_plan(&p, GpuType::Rtx4090, &opts()) {
            assert!(ours.makespan <= r4090.makespan * 1.02);
        }
    }

    #[test]
    fn uniform_composition_fits_budget_and_avail() {
        let avail = availability(1);
        let comp = uniform_composition(30.0, &avail);
        let cost: f64 = comp
            .iter()
            .enumerate()
            .map(|(n, &k)| k as f64 * GpuSpec::of(GpuType::ALL[n]).price_per_hour)
            .sum();
        assert!(cost <= 30.0 + 1e-9);
        for (n, &k) in comp.iter().enumerate() {
            assert!(k <= avail.counts[n]);
        }
        // Uses multiple types.
        assert!(comp.iter().filter(|&&k| k > 0).count() >= 4);
    }

    #[test]
    fn hexgen_uniform_worse_than_ours() {
        let p = problem(30.0);
        let ours = ours(&p);
        let comp = uniform_composition(30.0, &availability(1));
        let hex = hexgen_plan(&p, &comp, &opts()).unwrap();
        assert!(
            hex.makespan >= ours.makespan * 0.98,
            "hexgen {} vs ours {}",
            hex.makespan,
            ours.makespan
        );
    }

    #[test]
    fn hexgen_with_our_composition_still_loses_to_workload_aware() {
        // Figure 7 second bar: HexGen with the optimal composition still
        // loses because assignment is rate-proportional, not workload-aware.
        let p = problem(30.0);
        let ours = ours(&p);
        let comp_vec = ours.gpus_used(&p);
        let comp = [
            comp_vec[0], comp_vec[1], comp_vec[2], comp_vec[3], comp_vec[4], comp_vec[5],
        ];
        let hex = hexgen_plan(&p, &comp, &opts()).unwrap();
        assert!(
            hex.makespan >= ours.makespan * 0.98,
            "hexgen-opt {} vs ours {}",
            hex.makespan,
            ours.makespan
        );
    }

    #[test]
    fn ablations_degrade_or_match() {
        let p = problem(30.0);
        let ours = ours(&p);
        let cases: Vec<(&str, Option<ServingPlan>)> = vec![
            ("uniform-comp", ablation_uniform_composition(&p, &opts())),
            ("uniform-deploy", ablation_uniform_deployment(&p, &opts())),
            ("round-robin", ablation_round_robin(&p, &opts())),
        ];
        for (name, plan) in cases {
            let plan = plan.unwrap_or_else(|| panic!("{name} produced no plan"));
            assert!(
                plan.makespan >= ours.makespan * 0.95,
                "{name}: {} vs ours {}",
                plan.makespan,
                ours.makespan
            );
        }
    }

    #[test]
    fn round_robin_fractions_sum_to_one() {
        let p = problem(30.0);
        let plan = ablation_round_robin(&p, &opts()).unwrap();
        for w in 0..9 {
            if p.demands[0][w] <= 0.0 {
                continue;
            }
            let cover: f64 = plan.entries.iter().map(|e| e.fractions[w]).sum();
            assert!((cover - 1.0).abs() < 1e-6, "w{w} cover={cover}");
        }
    }

    #[test]
    fn planner_registry_covers_every_strategy_with_provenance() {
        let p = problem(30.0);
        let mut seen = Vec::new();
        for planner in all_planners(&opts()).iter_mut() {
            let report = planner.plan(&PlanRequest::new(&p));
            assert_eq!(report.provenance.strategy, planner.name());
            assert!(
                report.plan.is_some() != report.infeasible.is_some(),
                "{}: exactly one of plan/infeasible must be set",
                planner.name()
            );
            seen.push(planner.name());
        }
        assert!(seen.contains(&"bisection".to_string()));
        assert!(seen.contains(&"hexgen-uniform".to_string()));
        assert!(seen.iter().any(|n| n.starts_with("homogeneous-")));
        assert!(seen.iter().any(|n| n.starts_with("ablation-")));
    }
}
