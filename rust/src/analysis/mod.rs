//! `pallas-lint` — the in-repo invariant analyzer.
//!
//! A project-specific static-analysis pass over `rust/src` that enforces
//! the invariants the repo's correctness claims rest on: determinism zones
//! (bit-identical parallel B&B and sharded simulation), atomic-ordering
//! discipline, numerical hygiene, and panic-path ratcheting. The spot
//! tests (1-vs-N fingerprint checks) verify the invariants *hold today*;
//! the analyzer enforces them *by construction* on every change, before
//! any test runs.
//!
//! No AST crates exist offline, so the scanner is hand-rolled: a
//! comment/string-aware lexer ([`lexer`]), a path-based zone map
//! ([`zones`]), six rules with stable IDs ([`rules`], catalog in
//! `analysis/README.md`), span-accurate diagnostics ([`diag`]), and a
//! ratcheting baseline ([`baseline`]) — existing debt is frozen in
//! `analysis/baseline.json`, new violations fail, and fixes shrink the
//! file via `lint --update-baseline`.
//!
//! Entry points: the `hetserve lint` subcommand (CI gate) and
//! [`run_lint`] (used by the `pallas_lint` integration test).

pub mod baseline;
pub mod diag;
pub mod lexer;
pub mod rules;
pub mod zones;

use baseline::{ratchet, Baseline, RatchetOutcome};
use diag::{Diagnostic, RuleId, ALL_RULES};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// Options for one lint run.
#[derive(Debug, Default)]
pub struct LintOptions {
    /// Rewrite the baseline to the current violation counts (ratchetable
    /// rules only) instead of failing on them.
    pub update_baseline: bool,
    /// Print every violation (default prints failures + summary).
    pub verbose: bool,
}

/// Result of one lint run over the tree.
#[derive(Debug)]
pub struct LintRun {
    /// Every unsuppressed violation, including baseline-frozen ones.
    pub violations: Vec<Diagnostic>,
    /// Violations silenced by reasoned inline allows.
    pub suppressed: u64,
    /// Non-fatal notes (unused allows).
    pub notes: Vec<String>,
    /// Files scanned.
    pub files: usize,
    /// The ratchet diff against the committed baseline.
    pub outcome: RatchetOutcome,
    /// Human-readable report.
    pub report: String,
    /// `true` when new (non-frozen) violations exist — the CI gate.
    pub failed: bool,
}

/// Lint `src_root` against the baseline at `baseline_path`.
///
/// With `update_baseline`, the baseline file is rewritten to the current
/// counts (never recording zero-tolerance rules) and the run only fails on
/// zero-tolerance violations.
pub fn run_lint(
    src_root: &Path,
    baseline_path: &Path,
    opts: &LintOptions,
) -> anyhow::Result<LintRun> {
    let files = collect_rs_files(src_root)?;
    if files.is_empty() {
        anyhow::bail!("no .rs files under {} — wrong --root?", src_root.display());
    }

    let mut violations = Vec::new();
    let mut suppressed = 0u64;
    let mut notes = Vec::new();
    for path in &files {
        let rel = rel_key(src_root, path);
        let source = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        let scan = lexer::FileScan::scan(&source);
        let res = rules::check_file(&rel, zones::classify(&rel), &scan);
        violations.extend(res.violations);
        suppressed += res.suppressed as u64;
        notes.extend(res.notes);
    }

    let base = if baseline_path.exists() {
        let text = std::fs::read_to_string(baseline_path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", baseline_path.display()))?;
        Baseline::parse(&text)?
    } else {
        Baseline::empty()
    };

    if opts.update_baseline {
        let fresh = Baseline::from_violations(&violations);
        std::fs::write(baseline_path, fresh.to_json_string())
            .map_err(|e| anyhow::anyhow!("write {}: {e}", baseline_path.display()))?;
        let outcome = ratchet(&violations, &fresh);
        let failed = !outcome.failures.is_empty();
        let report = render(
            &violations,
            suppressed,
            &notes,
            files.len(),
            &outcome,
            opts,
            Some(baseline_path),
        );
        return Ok(LintRun {
            violations,
            suppressed,
            notes,
            files: files.len(),
            outcome,
            report,
            failed,
        });
    }

    let outcome = ratchet(&violations, &base);
    let failed = !outcome.failures.is_empty();
    let report = render(
        &violations,
        suppressed,
        &notes,
        files.len(),
        &outcome,
        opts,
        None,
    );
    Ok(LintRun {
        violations,
        suppressed,
        notes,
        files: files.len(),
        outcome,
        report,
        failed,
    })
}

/// All `.rs` files under `root`, depth-first, name-sorted at every level so
/// diagnostics and baselines are ordered deterministically on any platform.
fn collect_rs_files(root: &Path) -> anyhow::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<PathBuf> = std::fs::read_dir(&dir)
            .map_err(|e| anyhow::anyhow!("read dir {}: {e}", dir.display()))?
            .map(|e| e.map(|e| e.path()))
            .collect::<Result<_, _>>()
            .map_err(|e| anyhow::anyhow!("read dir {}: {e}", dir.display()))?;
        entries.sort();
        // Depth-first via the stack: push dirs reversed so pop order is
        // name-ascending.
        for entry in entries.iter().rev() {
            if entry.is_dir() {
                stack.push(entry.clone());
            }
        }
        for entry in entries {
            if entry.is_file() && entry.extension().is_some_and(|e| e == "rs") {
                out.push(entry);
            }
        }
    }
    out.sort();
    Ok(out)
}

fn rel_key(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

#[allow(clippy::too_many_arguments)]
fn render(
    violations: &[Diagnostic],
    suppressed: u64,
    notes: &[String],
    files: usize,
    outcome: &RatchetOutcome,
    opts: &LintOptions,
    updated: Option<&Path>,
) -> String {
    let mut s = String::new();

    for g in &outcome.failures {
        let _ = writeln!(
            s,
            "FAIL {} in {}: {} found, {} frozen in baseline — new violation(s):",
            g.rule, g.file, g.found, g.allowed
        );
        for d in &g.diags {
            let _ = writeln!(s, "{}", d.render());
        }
    }
    if opts.verbose {
        let failing: Vec<&Diagnostic> = outcome
            .failures
            .iter()
            .flat_map(|g| g.diags.iter())
            .collect();
        for d in violations {
            if !failing
                .iter()
                .any(|f| f.file == d.file && f.line == d.line && f.rule == d.rule)
            {
                let _ = writeln!(s, "frozen: {}", d.render());
            }
        }
    }
    for n in notes {
        let _ = writeln!(s, "note: {n}");
    }
    for (rule, file, from, to) in &outcome.shrink {
        let _ = writeln!(
            s,
            "ratchet: {rule} in {file} improved {from} -> {to}; run `lint --update-baseline` to lock it in"
        );
    }

    let mut per_rule: BTreeMap<&str, u64> = BTreeMap::new();
    for d in violations {
        *per_rule.entry(d.rule.as_str()).or_insert(0) += 1;
    }
    let counts = ALL_RULES
        .iter()
        .map(|r| format!("{}={}", r, per_rule.get(r.as_str()).copied().unwrap_or(0)))
        .collect::<Vec<_>>()
        .join(" ");
    let _ = writeln!(
        s,
        "pallas-lint: {files} files, {} violation(s) ({} frozen by baseline, {} new), {suppressed} allowed inline [{counts}]",
        violations.len(),
        outcome.frozen,
        violations.len() as u64 - outcome.frozen,
    );
    if let Some(p) = updated {
        let _ = writeln!(s, "baseline updated: {}", p.display());
    } else if !outcome.shrink.is_empty() {
        let _ = writeln!(s, "baseline can shrink: {} entr(ies) improved", outcome.shrink.len());
    }
    if outcome.failures.is_empty() {
        let _ = writeln!(s, "pallas-lint: OK");
    } else {
        let new: u64 = outcome
            .failures
            .iter()
            .map(|g| g.found - g.allowed)
            .sum();
        let _ = writeln!(s, "pallas-lint: FAILED — {new} new violation(s)");
    }
    s
}

/// Count current violations of one rule (used by tests asserting the
/// ratchet direction).
pub fn count_rule(run: &LintRun, rule: RuleId) -> u64 {
    run.violations.iter().filter(|d| d.rule == rule).count() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    /// End-to-end over a synthetic tree: write fixture files, lint, check
    /// ratchet + update flows.
    #[test]
    fn lint_tree_end_to_end() {
        let dir = std::env::temp_dir().join(format!("pallas_lint_e2e_{}", std::process::id()));
        let src = dir.join("src");
        std::fs::create_dir_all(src.join("milp")).expect("create fixture tree");
        std::fs::create_dir_all(src.join("sched")).expect("create fixture tree");

        // Deterministic-zone file with one D001 and one allowed D002.
        std::fs::write(
            src.join("milp/bounds.rs"),
            "use std::collections::HashMap;\n\
             // pallas-lint: allow(D002, deadline only; never in result bits)\n\
             fn f() { let t = Instant::now(); }\n",
        )
        .expect("write fixture");
        // General file with two P001s.
        std::fs::write(
            src.join("sched/mod.rs"),
            "fn a(x: Option<u32>) -> u32 { x.unwrap() }\n\
             fn b() { panic!(\"boom\"); }\n",
        )
        .expect("write fixture");

        let baseline = dir.join("baseline.json");
        let opts = LintOptions::default();

        // First run, no baseline: D001 fails (zero-tolerance) and P001
        // fails (no frozen debt yet).
        let run = run_lint(&src, &baseline, &opts).expect("lint runs");
        assert!(run.failed);
        assert_eq!(count_rule(&run, RuleId::D001), 1);
        assert_eq!(count_rule(&run, RuleId::P001), 2);
        assert_eq!(run.suppressed, 1, "the D002 allow counts as suppressed");

        // Update the baseline: P001 debt frozen, D001 still fails.
        let upd = LintOptions {
            update_baseline: true,
            ..Default::default()
        };
        let run = run_lint(&src, &baseline, &upd).expect("lint runs");
        assert!(run.failed, "zero-tolerance D001 must fail even on update");
        let text = std::fs::read_to_string(&baseline).expect("baseline written");
        assert!(text.contains("P001"));
        assert!(!text.contains("D001"), "D-rule must not be baselined: {text}");

        // Fix the D001; now the run passes against the frozen P001 debt.
        std::fs::write(
            src.join("milp/bounds.rs"),
            "use std::collections::BTreeMap;\n\
             // pallas-lint: allow(D002, deadline only; never in result bits)\n\
             fn f() { let t = Instant::now(); }\n",
        )
        .expect("write fixture");
        let run = run_lint(&src, &baseline, &opts).expect("lint runs");
        assert!(!run.failed, "report:\n{}", run.report);
        assert_eq!(run.outcome.frozen, 2);

        // Remove one P001: passes and offers a shrink.
        std::fs::write(
            src.join("sched/mod.rs"),
            "fn a(x: Option<u32>) -> u32 { x.expect(\"invariant: caller checked\") }\n\
             fn b() { panic!(\"boom\"); }\n",
        )
        .expect("write fixture");
        let run = run_lint(&src, &baseline, &opts).expect("lint runs");
        assert!(!run.failed);
        assert_eq!(run.outcome.shrink.len(), 1);
        let run = run_lint(&src, &baseline, &upd).expect("baseline shrinks");
        assert!(!run.failed);
        let text = std::fs::read_to_string(&baseline).expect("baseline present");
        let re = Baseline::parse(&text).expect("baseline parses");
        assert_eq!(re.total(RuleId::P001), 1, "ratchet shrank: {text}");

        // A brand-new P001 beyond the frozen count fails.
        std::fs::write(
            src.join("sched/mod.rs"),
            "fn a(x: Option<u32>) -> u32 { x.unwrap() }\n\
             fn b() { panic!(\"boom\"); }\n",
        )
        .expect("write fixture");
        let run = run_lint(&src, &baseline, &opts).expect("lint runs");
        assert!(run.failed, "new P001 beyond frozen debt must fail");

        std::fs::remove_dir_all(&dir).ok();
    }
}
