//! The `pallas-lint` rules and the per-file rule engine.
//!
//! Every rule works on the masked token stream from [`super::lexer`], so
//! string literals and comments can never false-positive. Test regions
//! (`#[cfg(test)]`, `#[test]`) are exempt from all rules — tests assert
//! bit-identity with exact float `==`, unwrap freely, and use `HashSet`
//! for order-insensitive membership checks.
//!
//! ## Suppression
//!
//! `// pallas-lint: allow(RULE, reason)` suppresses RULE on the same line
//! when the comment trails code, or on the next code line when the comment
//! stands alone. The reason is mandatory: an allow without one is itself a
//! violation (`L001`). Unused allows are reported as notes so stale
//! suppressions get cleaned up.
//!
//! ## Rule catalog (IDs are stable; see `analysis/README.md`)
//!
//! * **D001** — `HashMap`/`HashSet`/`RandomState` in a deterministic zone.
//!   Hash iteration order is seeded per-process; one stray iteration breaks
//!   the bit-identical claims. Use `BTreeMap`/`BTreeSet`/`Vec`.
//! * **D002** — `Instant::now` / `SystemTime` / `thread::current` in a
//!   deterministic zone. Wall-clock deadline reads are *intentional* in the
//!   planner (they feed the degradation ladder, not the plan bits) and
//!   carry documented allows.
//! * **D003** — entropy-seeded RNG construction (`thread_rng`,
//!   `from_entropy`, `OsRng`, `getrandom`) anywhere outside `util::rng`.
//!   All randomness flows from explicit `Xoshiro256` seeds.
//! * **A001** — `Ordering::Relaxed`/`Acquire`/`Release`/`AcqRel` must carry
//!   an adjacent `// ordering:` comment justifying why the chosen strength
//!   suffices. `SeqCst` is exempt (never too weak, only maybe slow).
//! * **F001** — bare `==`/`!=` against a float literal (or `f64::`/`f32::`
//!   constant). Exact comparisons of *computed* floats are almost always a
//!   bug; structural-zero tests in the solver inner loops are the known
//!   exception and carry allows.
//! * **P001** — `unwrap()` / `panic!` / `unreachable!` / `todo!` /
//!   `unimplemented!` in library code. Ratcheted against the baseline, not
//!   banned: `expect("invariant message")` is the sanctioned replacement,
//!   and `assert!`/`debug_assert!` are the sanctioned dynamic checks.

use super::diag::{Diagnostic, RuleId};
use super::lexer::{tokenize, FileScan, TokKind, Token};
use super::zones::{test_regions, ZoneSet};

/// A parsed `pallas-lint: allow(RULE, reason)` directive.
#[derive(Debug)]
struct Directive {
    rule: RuleId,
    /// 0-based line the directive suppresses.
    target: usize,
    /// 0-based line the directive was written on (for unused-allow notes).
    at: usize,
    used: bool,
}

/// Result of linting one file.
#[derive(Debug, Default)]
pub struct FileResult {
    /// Unsuppressed violations.
    pub violations: Vec<Diagnostic>,
    /// Count of violations silenced by a reasoned allow.
    pub suppressed: usize,
    /// Non-fatal observations (unused allows).
    pub notes: Vec<String>,
}

/// Lint one file: scan → tokenize → apply every rule → apply suppressions.
pub fn check_file(rel_path: &str, zone: ZoneSet, scan: &FileScan) -> FileResult {
    let toks = tokenize(scan);
    let is_test = test_regions(scan);
    let (mut directives, mut diags) = parse_directives(rel_path, zone, scan);

    let ctx = Ctx {
        rel_path,
        zone,
        scan,
        toks: &toks,
        is_test: &is_test,
    };
    rule_d001(&ctx, &mut diags);
    rule_d002(&ctx, &mut diags);
    rule_d003(&ctx, &mut diags);
    rule_a001(&ctx, &mut diags);
    rule_f001(&ctx, &mut diags);
    rule_p001(&ctx, &mut diags);

    // Suppression pass: a directive silences matching-rule diagnostics on
    // its target line. L001 (malformed directive) is never suppressible.
    let mut out = FileResult::default();
    for d in diags {
        let hit = d.rule != RuleId::L001
            && directives
                .iter_mut()
                .find(|dir| dir.rule == d.rule && dir.target == d.line - 1)
                .map(|dir| dir.used = true)
                .is_some();
        if hit {
            out.suppressed += 1;
        } else {
            out.violations.push(d);
        }
    }
    for dir in &directives {
        if !dir.used {
            out.notes.push(format!(
                "{}:{}: unused allow({}) — no matching violation on its target line; remove it",
                rel_path,
                dir.at + 1,
                dir.rule
            ));
        }
    }
    out.violations
        .sort_by(|a, b| (a.line, a.col).cmp(&(b.line, b.col)));
    out
}

struct Ctx<'a> {
    rel_path: &'a str,
    zone: ZoneSet,
    scan: &'a FileScan,
    toks: &'a [Token],
    is_test: &'a [bool],
}

impl<'a> Ctx<'a> {
    fn live(&self, t: &Token) -> bool {
        !self.is_test.get(t.line).copied().unwrap_or(false)
    }

    fn diag(&self, rule: RuleId, t: &Token, message: String) -> Diagnostic {
        Diagnostic {
            rule,
            file: self.rel_path.to_string(),
            line: t.line + 1,
            col: t.col,
            len: t.len,
            message,
            line_text: self.scan.lines[t.line].clone(),
            zone: self.zone,
        }
    }

    /// `true` when a comment containing `needle` sits on the token's line
    /// or within `above` lines directly above it.
    fn comment_near(&self, line: usize, above: usize, needle: &str) -> bool {
        let lo = line.saturating_sub(above);
        (lo..=line).any(|l| self.scan.comments[l].contains(needle))
    }
}

// ---- directives ----------------------------------------------------------

fn parse_directives(
    rel_path: &str,
    zone: ZoneSet,
    scan: &FileScan,
) -> (Vec<Directive>, Vec<Diagnostic>) {
    let mut dirs = Vec::new();
    let mut diags = Vec::new();
    for (lineno, comment) in scan.comments.iter().enumerate() {
        // Doc comments (///, //!, /**, /*!) are documentation *about* the
        // directive syntax, never directives themselves.
        let stripped = comment.trim_start();
        if ["///", "//!", "/**", "/*!"]
            .iter()
            .any(|p| stripped.starts_with(p))
        {
            continue;
        }
        let mut rest = comment.as_str();
        while let Some(pos) = rest.find("pallas-lint:") {
            let after = &rest[pos + "pallas-lint:".len()..];
            let body = after.trim_start();
            let mut bad = |msg: String| {
                diags.push(Diagnostic {
                    rule: RuleId::L001,
                    file: rel_path.to_string(),
                    line: lineno + 1,
                    col: 0,
                    len: scan.lines[lineno].chars().count(),
                    message: msg,
                    line_text: scan.lines[lineno].clone(),
                    zone,
                });
            };
            if let Some(open) = body.strip_prefix("allow(") {
                match balanced_paren(open) {
                    Some(inner) => match inner.split_once(',') {
                        Some((rule_s, reason)) if !reason.trim().is_empty() => {
                            match RuleId::parse(rule_s.trim()) {
                                Some(rule) => dirs.push(Directive {
                                    rule,
                                    target: directive_target(scan, lineno),
                                    at: lineno,
                                    used: false,
                                }),
                                None => bad(format!(
                                    "allow() names unknown rule '{}'",
                                    rule_s.trim()
                                )),
                            }
                        }
                        _ => bad(
                            "allow(RULE, reason) requires a non-empty reason — say why the \
                             invariant still holds"
                                .to_string(),
                        ),
                    },
                    None => bad("unterminated allow( directive".to_string()),
                }
            } else {
                bad(format!(
                    "unrecognised pallas-lint directive '{}' (expected allow(RULE, reason))",
                    body.split_whitespace().next().unwrap_or("")
                ));
            }
            rest = after;
        }
    }
    (dirs, diags)
}

/// Content up to the `)` matching the already-consumed `(`.
fn balanced_paren(s: &str) -> Option<&str> {
    let mut depth = 1usize;
    for (i, c) in s.char_indices() {
        match c {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    return Some(&s[..i]);
                }
            }
            _ => {}
        }
    }
    None
}

/// The line a directive suppresses: its own line when the comment trails
/// code, otherwise the next line that carries code.
fn directive_target(scan: &FileScan, lineno: usize) -> usize {
    if !scan.masked[lineno].trim().is_empty() {
        return lineno;
    }
    for l in lineno + 1..scan.masked.len() {
        if !scan.masked[l].trim().is_empty() {
            return l;
        }
    }
    lineno
}

// ---- D001: hash collections in deterministic zones -----------------------

fn rule_d001(ctx: &Ctx, out: &mut Vec<Diagnostic>) {
    if !ctx.zone.deterministic {
        return;
    }
    for t in ctx.toks {
        let Some(id) = t.ident() else { continue };
        if !ctx.live(t) {
            continue;
        }
        if matches!(id, "HashMap" | "HashSet" | "RandomState" | "hash_map" | "hash_set") {
            out.push(ctx.diag(
                RuleId::D001,
                t,
                format!(
                    "`{id}` in the deterministic zone: hash iteration order is \
                     seeded per-process and breaks bit-identical replay — use \
                     BTreeMap/BTreeSet/Vec, or allow with a reason"
                ),
            ));
        }
    }
}

// ---- D002: wall-clock / thread identity in deterministic zones -----------

fn rule_d002(ctx: &Ctx, out: &mut Vec<Diagnostic>) {
    if !ctx.zone.deterministic {
        return;
    }
    let toks = ctx.toks;
    for (i, t) in toks.iter().enumerate() {
        let Some(id) = t.ident() else { continue };
        if !ctx.live(t) {
            continue;
        }
        let flagged = match id {
            "Instant" => path_call(toks, i, "now"),
            "SystemTime" => true,
            "thread" => path_call(toks, i, "current"),
            _ => false,
        };
        if flagged {
            out.push(ctx.diag(
                RuleId::D002,
                t,
                format!(
                    "`{id}` read in the deterministic zone: wall-clock and thread \
                     identity vary run to run — thread results through explicit \
                     simulated time, or allow with a reason if the read only \
                     feeds a deadline/telemetry (never the result bits)"
                ),
            ));
        }
    }
}

/// `toks[i]` is an ident; true when followed by `:: member`.
fn path_call(toks: &[Token], i: usize, member: &str) -> bool {
    toks.get(i + 1).is_some_and(|t| t.is_punct("::"))
        && toks.get(i + 2).and_then(|t| t.ident()) == Some(member)
}

// ---- D003: entropy-seeded RNG outside util::rng --------------------------

fn rule_d003(ctx: &Ctx, out: &mut Vec<Diagnostic>) {
    if ctx.rel_path == "util/rng.rs" {
        return;
    }
    for t in ctx.toks {
        let Some(id) = t.ident() else { continue };
        if !ctx.live(t) {
            continue;
        }
        if matches!(
            id,
            "thread_rng" | "ThreadRng" | "from_entropy" | "OsRng" | "getrandom" | "EntropyRng"
        ) {
            out.push(ctx.diag(
                RuleId::D003,
                t,
                format!(
                    "`{id}`: entropy-seeded RNG construction outside util::rng — \
                     every random stream must flow from an explicit Xoshiro256 \
                     seed so runs are replayable"
                ),
            ));
        }
    }
}

// ---- A001: atomic orderings need a `// ordering:` justification ----------

fn rule_a001(ctx: &Ctx, out: &mut Vec<Diagnostic>) {
    let toks = ctx.toks;
    for (i, t) in toks.iter().enumerate() {
        let Some(id) = t.ident() else { continue };
        if !matches!(id, "Relaxed" | "Acquire" | "Release" | "AcqRel") {
            continue;
        }
        if i == 0 || !toks[i - 1].is_punct("::") || !ctx.live(t) {
            continue;
        }
        // `cmp::Ordering` has no variants by these names, so `::Relaxed`
        // etc. is an atomic ordering regardless of the path prefix
        // (`Ordering::`, `AtomicOrd::`, `atomic::Ordering::`).
        if !ctx.comment_near(t.line, 3, "ordering:") {
            out.push(ctx.diag(
                RuleId::A001,
                t,
                format!(
                    "`::{id}` without an adjacent `// ordering:` comment — state \
                     why this strength suffices (what synchronises the access, \
                     or why no synchronisation is needed)"
                ),
            ));
        }
    }
}

// ---- F001: bare float comparisons ----------------------------------------

fn rule_f001(ctx: &Ctx, out: &mut Vec<Diagnostic>) {
    let toks = ctx.toks;
    for (i, t) in toks.iter().enumerate() {
        let TokKind::Punct(op) = &t.kind else { continue };
        if op != "==" && op != "!=" {
            continue;
        }
        if !ctx.live(t) {
            continue;
        }
        let lhs_float = i > 0 && (toks[i - 1].is_float() || float_const_before(toks, i));
        let rhs_float = toks.get(i + 1).map(|n| n.is_float()).unwrap_or(false)
            || float_const_after(toks, i)
            // `x == -1.5`: the literal hides behind a unary minus.
            || (toks.get(i + 1).map(|n| n.is_punct("-")).unwrap_or(false)
                && toks.get(i + 2).map(|n| n.is_float()).unwrap_or(false));
        if lhs_float || rhs_float {
            out.push(ctx.diag(
                RuleId::F001,
                t,
                format!(
                    "bare `{op}` against a float literal — computed floats carry \
                     rounding error; compare with a tolerance helper, or allow \
                     with a reason when the value is exact by construction"
                ),
            ));
        }
    }
}

const FLOAT_CONSTS: &[&str] = &[
    "INFINITY",
    "NEG_INFINITY",
    "NAN",
    "MAX",
    "MIN",
    "EPSILON",
    "MIN_POSITIVE",
];

/// `... f64::CONST ==` — constant path ends right before the operator.
fn float_const_before(toks: &[Token], op: usize) -> bool {
    op >= 3
        && toks[op - 1]
            .ident()
            .is_some_and(|id| FLOAT_CONSTS.contains(&id))
        && toks[op - 2].is_punct("::")
        && matches!(toks[op - 3].ident(), Some("f32") | Some("f64"))
}

/// `== f64::CONST ...`.
fn float_const_after(toks: &[Token], op: usize) -> bool {
    matches!(
        toks.get(op + 1).and_then(|t| t.ident()),
        Some("f32") | Some("f64")
    ) && toks.get(op + 2).is_some_and(|t| t.is_punct("::"))
        && toks
            .get(op + 3)
            .and_then(|t| t.ident())
            .is_some_and(|id| FLOAT_CONSTS.contains(&id))
}

// ---- P001: panic paths in library code -----------------------------------

fn rule_p001(ctx: &Ctx, out: &mut Vec<Diagnostic>) {
    let toks = ctx.toks;
    for (i, t) in toks.iter().enumerate() {
        let Some(id) = t.ident() else { continue };
        if !ctx.live(t) {
            continue;
        }
        let flagged = match id {
            // `.unwrap()` — method position only, so local fns named
            // `unwrap_*` don't trip.
            "unwrap" => {
                i > 0
                    && toks[i - 1].is_punct(".")
                    && toks.get(i + 1).is_some_and(|n| n.is_punct("("))
            }
            "panic" | "unreachable" | "todo" | "unimplemented" => {
                toks.get(i + 1).is_some_and(|n| n.is_punct("!"))
            }
            _ => false,
        };
        if flagged {
            out.push(ctx.diag(
                RuleId::P001,
                t,
                format!(
                    "`{id}` panic-path in library code — propagate with `?`/anyhow \
                     or use `expect(\"invariant: ...\")` naming what guarantees \
                     success (ratcheted against analysis/baseline.json)"
                ),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::zones::classify;

    fn lint(rel: &str, src: &str) -> FileResult {
        check_file(rel, classify(rel), &FileScan::scan(src))
    }

    fn rules_of(r: &FileResult) -> Vec<&'static str> {
        r.violations.iter().map(|d| d.rule.as_str()).collect()
    }

    // ---- D001 ----

    #[test]
    fn d001_positive_in_deterministic_zone() {
        let r = lint("sim/engine.rs", "use std::collections::HashMap;\n");
        assert_eq!(rules_of(&r), vec!["D001"]);
        assert_eq!(r.violations[0].line, 1);
        assert!(r.violations[0].message.contains("bit-identical"));
    }

    #[test]
    fn d001_negative_outside_zone() {
        let r = lint("telemetry/mod.rs", "use std::collections::HashMap;\n");
        assert!(rules_of(&r).is_empty());
    }

    #[test]
    fn d001_string_and_comment_traps() {
        let src = "let s = \"HashMap\"; // HashMap in a comment\n/* HashSet */\n";
        let r = lint("milp/bounds.rs", src);
        assert!(rules_of(&r).is_empty(), "{:?}", r.violations);
    }

    #[test]
    fn d001_suppressed_with_reason() {
        let src = "// pallas-lint: allow(D001, keys are sorted before iteration)\n\
                   use std::collections::HashMap;\n";
        let r = lint("milp/bounds.rs", src);
        assert!(r.violations.is_empty());
        assert_eq!(r.suppressed, 1);
        assert!(r.notes.is_empty(), "allow was used: {:?}", r.notes);
    }

    #[test]
    fn d001_exempt_in_tests() {
        let src = "fn lib() {}\n#[cfg(test)]\nmod tests {\n    use std::collections::HashSet;\n}\n";
        let r = lint("util/rng.rs", src);
        assert!(rules_of(&r).is_empty());
    }

    // ---- D002 ----

    #[test]
    fn d002_instant_now_positive() {
        let r = lint("sim/timeline.rs", "let t = Instant::now();\n");
        assert_eq!(rules_of(&r), vec!["D002"]);
    }

    #[test]
    fn d002_instant_param_is_fine() {
        // Accepting an Instant that the caller measured is not a read.
        let r = lint("milp/branch_bound.rs", "fn f(start: Instant) -> bool { true }\n");
        assert!(rules_of(&r).is_empty());
    }

    #[test]
    fn d002_thread_current_positive() {
        let r = lint("sim/engine.rs", "let id = thread::current().id();\n");
        assert_eq!(rules_of(&r), vec!["D002"]);
    }

    #[test]
    fn d002_trailing_allow_same_line() {
        let src = "let t = Instant::now(); // pallas-lint: allow(D002, deadline only)\n";
        let r = lint("milp/branch_bound.rs", src);
        assert!(r.violations.is_empty());
        assert_eq!(r.suppressed, 1);
    }

    // ---- D003 ----

    #[test]
    fn d003_everywhere_except_rng() {
        let r = lint("workload/synth.rs", "let r = thread_rng();\n");
        assert_eq!(rules_of(&r), vec!["D003"]);
        let ok = lint("util/rng.rs", "fn from_entropy() {}\n");
        assert!(rules_of(&ok).is_empty());
    }

    // ---- A001 ----

    #[test]
    fn a001_unjustified_relaxed() {
        let r = lint("telemetry/mod.rs", "x.load(Ordering::Relaxed);\n");
        assert_eq!(rules_of(&r), vec!["A001"]);
    }

    #[test]
    fn a001_justified_same_line_and_above() {
        let src = "x.load(Ordering::Relaxed); // ordering: monotonic counter, no sync\n\
                   // ordering: flag is advisory; readers tolerate staleness\n\
                   y.store(1, Ordering::Release);\n";
        let r = lint("telemetry/mod.rs", src);
        assert!(rules_of(&r).is_empty(), "{:?}", r.violations);
    }

    #[test]
    fn a001_seqcst_exempt_and_cmp_ordering_ignored() {
        let src = "x.load(Ordering::SeqCst);\nlet e = cmp::Ordering::Equal;\n";
        let r = lint("util/threadpool.rs", src);
        assert!(rules_of(&r).is_empty());
    }

    #[test]
    fn a001_alias_path_still_caught() {
        let r = lint("milp/branch_bound.rs", "x.fetch_min(k, AtomicOrd::Relaxed);\n");
        assert_eq!(rules_of(&r), vec!["A001"]);
    }

    // ---- F001 ----

    #[test]
    fn f001_literal_both_sides() {
        let r = lint("sched/formulation.rs", "if x == 0.5 { }\nif 1.0 != y { }\n");
        assert_eq!(rules_of(&r), vec!["F001", "F001"]);
    }

    #[test]
    fn f001_float_const_path() {
        let r = lint("sched/formulation.rs", "if x == f64::INFINITY { }\n");
        assert_eq!(rules_of(&r), vec!["F001"]);
    }

    #[test]
    fn f001_integer_compare_is_fine() {
        let r = lint("sched/formulation.rs", "if n == 3 { }\nif m != 0x1E { }\n");
        assert!(rules_of(&r).is_empty());
    }

    #[test]
    fn f001_exempt_in_tests() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() { assert!(x == 1.5); }\n}\n";
        let r = lint("sched/formulation.rs", src);
        assert!(rules_of(&r).is_empty());
    }

    // ---- P001 ----

    #[test]
    fn p001_unwrap_and_macros() {
        let src = "let v = x.unwrap();\npanic!(\"boom\");\nunreachable!();\n";
        let r = lint("sched/planner.rs", src);
        assert_eq!(rules_of(&r), vec!["P001", "P001", "P001"]);
    }

    #[test]
    fn p001_expect_and_asserts_sanctioned() {
        let src = "let v = x.expect(\"invariant: basis dims checked above\");\n\
                   assert!(ok);\ndebug_assert!(residual < tol);\n";
        let r = lint("sched/planner.rs", src);
        assert!(rules_of(&r).is_empty());
    }

    #[test]
    fn p001_local_fn_named_unwrap_not_flagged() {
        let r = lint(
            "sched/planner.rs",
            "fn unwrap_or_cached(x: u32) {}\nlet y = unwrap_helper();\n",
        );
        assert!(rules_of(&r).is_empty());
    }

    // ---- directives / L001 ----

    #[test]
    fn l001_missing_reason() {
        let r = lint(
            "sim/engine.rs",
            "// pallas-lint: allow(D001)\nuse std::collections::HashMap;\n",
        );
        let ids = rules_of(&r);
        assert!(ids.contains(&"L001"), "{ids:?}");
        assert!(ids.contains(&"D001"), "bad allow must not suppress: {ids:?}");
    }

    #[test]
    fn l001_unknown_rule() {
        let r = lint("sim/engine.rs", "// pallas-lint: allow(D999, whatever)\n");
        assert_eq!(rules_of(&r), vec!["L001"]);
    }

    #[test]
    fn unused_allow_noted() {
        let r = lint("sim/engine.rs", "// pallas-lint: allow(D001, stale)\nlet x = 1;\n");
        assert!(r.violations.is_empty());
        assert_eq!(r.notes.len(), 1);
        assert!(r.notes[0].contains("unused allow(D001)"));
    }

    #[test]
    fn allow_wrong_rule_does_not_suppress() {
        let src = "// pallas-lint: allow(D002, wrong rule)\nuse std::collections::HashMap;\n";
        let r = lint("sim/engine.rs", src);
        assert_eq!(rules_of(&r), vec!["D001"]);
        assert_eq!(r.notes.len(), 1, "the D002 allow is unused");
    }
}
