//! Comment- and string-aware scanning of Rust source.
//!
//! `pallas-lint` runs offline with no AST crates available, so this module
//! hand-rolls the one lexical fact every rule depends on: *which bytes of a
//! file are code*. [`FileScan::scan`] walks a source file once with a small
//! state machine and produces, per line, a **masked** copy in which every
//! comment, string literal, and char literal is blanked to spaces (columns
//! preserved), plus the extracted comment text per line (directives like
//! `pallas-lint: allow(...)` and `ordering:` justifications live in
//! comments). Rules then tokenize the masked text with [`tokenize`] and can
//! never false-positive on `"HashMap"` inside a string or a commented-out
//! `Instant::now()`.
//!
//! Handled Rust lexical edge cases: nested block comments, escaped string
//! chars, multi-line strings, raw strings `r#"..."#` (any hash depth), byte
//! and byte-raw strings, char literals vs lifetimes (`'a'` vs `<'a>`), and
//! raw identifiers (`r#type` stays code).

/// One scanned source file: raw lines, code-only masked lines, and the
/// comment text found on each line.
#[derive(Debug)]
pub struct FileScan {
    /// Raw source lines, without trailing newlines.
    pub lines: Vec<String>,
    /// Same lines with comments / string literals / char literals replaced
    /// by spaces. Column positions are preserved, so token spans computed on
    /// the masked text are valid for the raw text.
    pub masked: Vec<String>,
    /// Concatenated comment text per line (empty when the line carries no
    /// comment). Block comments contribute their content to every line they
    /// span.
    pub comments: Vec<String>,
}

/// Scanner state carried across lines.
enum State {
    Code,
    /// Inside `/* ... */`; the depth supports Rust's nested block comments.
    Block { depth: usize },
    /// Inside a `"..."` string (escapes handled inline; may span lines).
    Str,
    /// Inside a raw string terminated by `"` followed by `hashes` `#`s.
    RawStr { hashes: usize },
}

impl FileScan {
    pub fn scan(source: &str) -> FileScan {
        let mut lines: Vec<String> = Vec::new();
        let mut masked: Vec<String> = Vec::new();
        let mut comments: Vec<String> = Vec::new();
        let mut state = State::Code;

        for raw_line in source.split('\n') {
            let chars: Vec<char> = raw_line.chars().collect();
            let n = chars.len();
            let mut out: Vec<char> = Vec::with_capacity(n);
            let mut comment = String::new();
            let mut i = 0usize;

            while i < n {
                match state {
                    State::Block { depth } => {
                        if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                            state = State::Block { depth: depth + 1 };
                            comment.push_str("/*");
                            out.push(' ');
                            out.push(' ');
                            i += 2;
                        } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                            state = if depth == 1 {
                                State::Code
                            } else {
                                State::Block { depth: depth - 1 }
                            };
                            comment.push_str("*/");
                            out.push(' ');
                            out.push(' ');
                            i += 2;
                        } else {
                            comment.push(chars[i]);
                            out.push(if chars[i] == '\t' { '\t' } else { ' ' });
                            i += 1;
                        }
                    }
                    State::Str => {
                        if chars[i] == '\\' && i + 1 < n {
                            out.push(' ');
                            out.push(' ');
                            i += 2;
                        } else if chars[i] == '"' {
                            state = State::Code;
                            out.push(' ');
                            i += 1;
                        } else {
                            out.push(if chars[i] == '\t' { '\t' } else { ' ' });
                            i += 1;
                        }
                    }
                    State::RawStr { hashes } => {
                        if chars[i] == '"' {
                            let have = chars[i + 1..]
                                .iter()
                                .take(hashes)
                                .take_while(|&&c| c == '#')
                                .count();
                            if have == hashes {
                                state = State::Code;
                                for _ in 0..=hashes {
                                    out.push(' ');
                                }
                                i += 1 + hashes;
                                continue;
                            }
                        }
                        out.push(if chars[i] == '\t' { '\t' } else { ' ' });
                        i += 1;
                    }
                    State::Code => {
                        let c = chars[i];
                        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
                            // Line comment: the rest of the line.
                            comment.push_str(&chars[i..].iter().collect::<String>());
                            for _ in i..n {
                                out.push(' ');
                            }
                            i = n;
                        } else if c == '/' && i + 1 < n && chars[i + 1] == '*' {
                            state = State::Block { depth: 1 };
                            comment.push_str("/*");
                            out.push(' ');
                            out.push(' ');
                            i += 2;
                        } else if c == '"' {
                            state = State::Str;
                            out.push(' ');
                            i += 1;
                        } else if c == '\'' {
                            // Char literal or lifetime. `'\...'` and `'x'`
                            // are literals; `'ident` (no closing quote right
                            // after one char) is a lifetime and stays code.
                            if i + 1 < n && chars[i + 1] == '\\' {
                                // Escaped char literal: mask to closing quote.
                                let mut j = i + 2;
                                while j < n && chars[j] != '\'' {
                                    j += 1;
                                }
                                for _ in i..(j + 1).min(n) {
                                    out.push(' ');
                                }
                                i = (j + 1).min(n);
                            } else if i + 2 < n && chars[i + 2] == '\'' && chars[i + 1] != '\'' {
                                out.push(' ');
                                out.push(' ');
                                out.push(' ');
                                i += 3;
                            } else {
                                out.push('\'');
                                i += 1;
                            }
                        } else if is_ident_start(c) {
                            // Consume the identifier whole so raw-string
                            // prefixes are only recognised when the entire
                            // identifier is `r`, `b`, or `br`.
                            let mut j = i + 1;
                            while j < n && is_ident_continue(chars[j]) {
                                j += 1;
                            }
                            let ident: String = chars[i..j].iter().collect();
                            let is_raw_prefix = matches!(ident.as_str(), "r" | "b" | "br");
                            if is_raw_prefix {
                                let mut k = j;
                                let mut hashes = 0usize;
                                while k < n && chars[k] == '#' {
                                    hashes += 1;
                                    k += 1;
                                }
                                if k < n && chars[k] == '"' {
                                    if ident == "b" && hashes == 0 {
                                        // b"..." is an escaped byte string.
                                        state = State::Str;
                                    } else if hashes == 0 && ident == "r" {
                                        state = State::RawStr { hashes: 0 };
                                    } else if hashes > 0 {
                                        state = State::RawStr { hashes };
                                    } else {
                                        // br"..." (no hashes): raw semantics.
                                        state = State::RawStr { hashes: 0 };
                                    }
                                    for _ in i..=k {
                                        out.push(' ');
                                    }
                                    i = k + 1;
                                    continue;
                                }
                            }
                            for ch in &chars[i..j] {
                                out.push(*ch);
                            }
                            i = j;
                        } else {
                            out.push(c);
                            i += 1;
                        }
                    }
                }
            }

            lines.push(raw_line.to_string());
            masked.push(out.into_iter().collect());
            comments.push(comment);
        }

        FileScan {
            lines,
            masked,
            comments,
        }
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// A token produced from masked code text.
#[derive(Debug, Clone, PartialEq)]
pub enum TokKind {
    Ident(String),
    /// Numeric literal; `is_float` when it has a decimal point, a decimal
    /// exponent, or an `f32`/`f64` suffix.
    Num { is_float: bool },
    /// Operator / punctuation, multi-char ops (`::`, `==`, `!=`, ...) fused.
    Punct(String),
}

/// One token with its position (0-based line, 0-based column, char length).
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokKind,
    pub line: usize,
    pub col: usize,
    pub len: usize,
}

impl Token {
    pub fn ident(&self) -> Option<&str> {
        match &self.kind {
            TokKind::Ident(s) => Some(s),
            _ => None,
        }
    }
    pub fn is_punct(&self, p: &str) -> bool {
        matches!(&self.kind, TokKind::Punct(s) if s == p)
    }
    pub fn is_float(&self) -> bool {
        matches!(self.kind, TokKind::Num { is_float: true })
    }
}

const MULTI_PUNCT: &[&str] = &[
    "::", "==", "!=", "<=", ">=", "->", "=>", "..", "&&", "||", "+=", "-=", "*=", "/=",
];

/// Tokenize the masked lines of a [`FileScan`] into a flat stream.
pub fn tokenize(scan: &FileScan) -> Vec<Token> {
    let mut toks = Vec::new();
    for (lineno, line) in scan.masked.iter().enumerate() {
        let chars: Vec<char> = line.chars().collect();
        let n = chars.len();
        let mut i = 0usize;
        while i < n {
            let c = chars[i];
            if c.is_whitespace() {
                i += 1;
            } else if is_ident_start(c) {
                let mut j = i + 1;
                while j < n && is_ident_continue(chars[j]) {
                    j += 1;
                }
                toks.push(Token {
                    kind: TokKind::Ident(chars[i..j].iter().collect()),
                    line: lineno,
                    col: i,
                    len: j - i,
                });
                i = j;
            } else if c.is_ascii_digit() {
                let (len, is_float) = lex_number(&chars[i..]);
                toks.push(Token {
                    kind: TokKind::Num { is_float },
                    line: lineno,
                    col: i,
                    len,
                });
                i += len;
            } else {
                let two: String = chars[i..(i + 2).min(n)].iter().collect();
                if MULTI_PUNCT.contains(&two.as_str()) {
                    toks.push(Token {
                        kind: TokKind::Punct(two),
                        line: lineno,
                        col: i,
                        len: 2,
                    });
                    i += 2;
                } else {
                    toks.push(Token {
                        kind: TokKind::Punct(c.to_string()),
                        line: lineno,
                        col: i,
                        len: 1,
                    });
                    i += 1;
                }
            }
        }
    }
    toks
}

/// Length and floatness of the numeric literal starting at `chars[0]`
/// (which is an ASCII digit). Understands `_` separators, hex/oct/bin
/// prefixes (never float), decimal points (but not method calls like
/// `2.max(..)` or tuple access), exponents, and type suffixes.
fn lex_number(chars: &[char]) -> (usize, bool) {
    let n = chars.len();
    let mut i = 1usize;
    let mut is_float = false;

    // Radix-prefixed integers can contain hex 'e'/'E'; never floats.
    if chars[0] == '0' && i < n && matches!(chars[i], 'x' | 'o' | 'b') {
        i += 1;
        while i < n && (chars[i].is_ascii_alphanumeric() || chars[i] == '_') {
            i += 1;
        }
        return (i, false);
    }

    while i < n && (chars[i].is_ascii_digit() || chars[i] == '_') {
        i += 1;
    }
    // A '.' continues the number only when followed by a digit (or end /
    // non-identifier), so `1.max(2)` and `tuple.0` stay integers.
    if i < n && chars[i] == '.' {
        let next = chars.get(i + 1);
        let continues = match next {
            None => true,
            Some(c) => c.is_ascii_digit() || !(is_ident_start(*c) || *c == '.'),
        };
        if continues {
            is_float = true;
            i += 1;
            while i < n && (chars[i].is_ascii_digit() || chars[i] == '_') {
                i += 1;
            }
        }
    }
    // Decimal exponent.
    if i < n && matches!(chars[i], 'e' | 'E') {
        let mut j = i + 1;
        if j < n && matches!(chars[j], '+' | '-') {
            j += 1;
        }
        if j < n && chars[j].is_ascii_digit() {
            is_float = true;
            i = j;
            while i < n && (chars[i].is_ascii_digit() || chars[i] == '_') {
                i += 1;
            }
        }
    }
    // Type suffix (f64 makes it a float; u32 etc. keep it an int).
    if i < n && is_ident_start(chars[i]) {
        let mut j = i;
        while j < n && is_ident_continue(chars[j]) {
            j += 1;
        }
        let suffix: String = chars[i..j].iter().collect();
        if suffix == "f32" || suffix == "f64" {
            is_float = true;
            i = j;
        } else if matches!(
            suffix.as_str(),
            "u8" | "u16" | "u32" | "u64" | "u128" | "usize" | "i8" | "i16" | "i32" | "i64"
                | "i128" | "isize"
        ) {
            i = j;
        }
    }
    (i, is_float)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn masked(src: &str) -> Vec<String> {
        FileScan::scan(src).masked
    }

    #[test]
    fn line_comments_are_masked_and_captured() {
        let s = FileScan::scan("let x = 1; // HashMap::new()\ncode();");
        assert!(!s.masked[0].contains("HashMap"));
        assert!(s.comments[0].contains("HashMap::new()"));
        assert_eq!(s.masked[1], "code();");
    }

    #[test]
    fn strings_are_masked_columns_preserved() {
        let m = masked(r#"let s = "Instant::now()"; foo();"#);
        assert!(!m[0].contains("Instant"));
        // Column positions survive masking.
        assert_eq!(m[0].find("foo"), Some(26));
    }

    #[test]
    fn nested_block_comments() {
        let m = masked("a /* x /* y */ z */ b");
        assert_eq!(m[0].trim(), "a                   b".trim());
        assert!(m[0].contains('a') && m[0].contains('b'));
        assert!(!m[0].contains('x') && !m[0].contains('z'));
    }

    #[test]
    fn raw_strings_any_hash_depth() {
        let m = masked(r##"let s = r#"unwrap() "quoted""#; t();"##);
        assert!(!m[0].contains("unwrap"));
        assert!(m[0].contains("t();"));
    }

    #[test]
    fn multiline_string_state_carries() {
        let m = masked("let s = \"line one\nHashMap here\"; done();");
        assert!(!m[1].contains("HashMap"));
        assert!(m[1].contains("done();"));
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let m = masked("let c = '\"'; fn f<'a>(x: &'a str) {} let q = 'x';");
        // The quote char literal must not open a string state.
        assert!(m[0].contains("fn f<'a>"));
        assert!(!m[0].contains("'x'"));
    }

    #[test]
    fn escaped_char_literal() {
        let m = masked(r"let c = '\n'; let d = '\''; ok();");
        assert!(m[0].contains("ok();"));
        assert!(!m[0].contains('n') || m[0].find("ok").is_some());
    }

    #[test]
    fn raw_identifier_stays_code() {
        let m = masked("let r#type = 1; use_it(r#type);");
        assert!(m[0].contains("type"));
    }

    #[test]
    fn number_lexing_floatness() {
        let scan = FileScan::scan(
            "a == 1.5; b == 2; c == 1e-3; d == 0x1E; e == 3f64; f == 2.max(1); g == 1_000.0;",
        );
        let toks = tokenize(&scan);
        let floats: Vec<bool> = toks
            .iter()
            .filter_map(|t| match t.kind {
                TokKind::Num { is_float } => Some(is_float),
                _ => None,
            })
            .collect();
        // 1.5 float, 2 int, 1e-3 float, 0x1E int, 3f64 float, 2 int (then
        // max(1) int), 1_000.0 float.
        assert_eq!(floats, vec![true, false, true, false, true, false, false, true]);
    }

    #[test]
    fn multi_char_puncts_fused() {
        let scan = FileScan::scan("a::b == c != d -> e => f");
        let toks = tokenize(&scan);
        let puncts: Vec<String> = toks
            .iter()
            .filter_map(|t| match &t.kind {
                TokKind::Punct(p) => Some(p.clone()),
                _ => None,
            })
            .collect();
        assert_eq!(puncts, vec!["::", "==", "!=", "->", "=>"]);
    }
}
