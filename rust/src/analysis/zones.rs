//! Zone classification: which invariants each file must uphold.
//!
//! The analyzer's zone map is *path-based* — a file's location in
//! `rust/src` decides which rule families apply to it:
//!
//! * **Deterministic zone** — code whose observable behaviour must be a
//!   pure function of its explicit seeds and inputs, because the repo's
//!   headline claims (bit-identical parallel B&B, bit-identical sharded
//!   simulation at any thread count, replayable fault plans) rest on it.
//!   D-rules (`D001`–`D003`) apply here.
//! * **Hot zone** — pivot/decode inner loops where per-iteration costs are
//!   budgeted. Currently informational: diagnostics are tagged with the
//!   zone so reviewers see when a finding sits on a hot path; dedicated
//!   H-rules can hang off this classification later.
//! * **General** — everything else; only the global rules (`A001`, `F001`,
//!   `P001`, `D003`) apply.
//!
//! Test regions (`#[cfg(test)]` items and `#[test]` functions) are exempt
//! from every rule: tests deliberately use exact float equality for
//! bit-identity assertions, unwrap freely, and may use `HashSet` for
//! order-insensitive checks.

use super::lexer::FileScan;

/// Zone membership of one file (a file can be both deterministic and hot:
/// `sim/engine.rs` is the sharded decode loop *and* a determinism claim).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ZoneSet {
    pub deterministic: bool,
    pub hot: bool,
}

impl ZoneSet {
    pub fn label(&self) -> &'static str {
        match (self.deterministic, self.hot) {
            (true, true) => "deterministic+hot",
            (true, false) => "deterministic",
            (false, true) => "hot",
            (false, false) => "general",
        }
    }
}

/// Files (relative to `rust/src`, `/`-separated) in the deterministic zone.
///
/// A trailing `/` entry claims the whole directory. This list is the one
/// place the zone map lives; `analysis/README.md` documents the rationale
/// per entry.
const DETERMINISTIC: &[&str] = &[
    "milp/",
    "sim/engine.rs",
    "sim/timeline.rs",
    "workload/stream.rs",
    "workload/drift.rs",
    "cloud/faults.rs",
    "util/rng.rs",
    "sched/binary_search.rs",
];

/// Pivot/decode inner-loop files (see module docs).
const HOT: &[&str] = &[
    "milp/bounds.rs",
    "milp/factor.rs",
    "milp/dense.rs",
    "sim/engine.rs",
];

fn matches_any(rel: &str, entries: &[&str]) -> bool {
    entries.iter().any(|e| {
        if let Some(dir) = e.strip_suffix('/') {
            rel.starts_with(dir) && rel.as_bytes().get(dir.len()) == Some(&b'/')
        } else {
            rel == *e
        }
    })
}

/// Classify a file by its path relative to the `rust/src` root.
pub fn classify(rel_path: &str) -> ZoneSet {
    ZoneSet {
        deterministic: matches_any(rel_path, DETERMINISTIC),
        hot: matches_any(rel_path, HOT),
    }
}

/// Per-line test-region map: `true` for lines belonging to a `#[cfg(test)]`
/// item (conventionally `mod tests { ... }`) or a `#[test]` function.
///
/// Works on masked text, so braces inside strings/comments cannot desync
/// the depth tracking. An attributed item extends to the matching `}` of
/// its first top-level `{`, or to the first top-level `;` for brace-less
/// items (`#[cfg(test)] use ...;`).
pub fn test_regions(scan: &FileScan) -> Vec<bool> {
    let n = scan.masked.len();
    let mut is_test = vec![false; n];
    let mut line = 0usize;
    while line < n {
        let code = scan.masked[line].trim();
        if code.starts_with("#[cfg(test)]") || code.starts_with("#[test]") {
            let end = item_end(scan, line);
            for l in line..=end.min(n - 1) {
                is_test[l] = true;
            }
            line = end + 1;
        } else {
            line += 1;
        }
    }
    is_test
}

/// Last line (0-based) of the item starting at `start` (the attribute
/// line). Scans forward tracking brace depth on masked text.
fn item_end(scan: &FileScan, start: usize) -> usize {
    let mut depth = 0i64;
    let mut seen_brace = false;
    for (off, masked) in scan.masked[start..].iter().enumerate() {
        for ch in masked.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    seen_brace = true;
                }
                '}' => {
                    depth -= 1;
                    if seen_brace && depth == 0 {
                        return start + off;
                    }
                }
                ';' if !seen_brace && depth == 0 => {
                    // Brace-less item (`#[cfg(test)] use foo;`) terminated
                    // by `;` before any block opens.
                    return start + off;
                }
                _ => {}
            }
        }
    }
    scan.masked.len().saturating_sub(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zone_map_paths() {
        assert!(classify("milp/bounds.rs").deterministic);
        assert!(classify("milp/bounds.rs").hot);
        assert!(classify("milp/branch_bound.rs").deterministic);
        assert!(!classify("milp/branch_bound.rs").hot);
        assert!(classify("sim/engine.rs").deterministic);
        assert!(classify("sim/engine.rs").hot);
        assert!(classify("sim/timeline.rs").deterministic);
        assert!(!classify("sim/closed_loop.rs").deterministic);
        assert!(classify("util/rng.rs").deterministic);
        assert!(!classify("util/rng_extras.rs").deterministic);
        assert!(!classify("telemetry/mod.rs").deterministic);
        assert_eq!(classify("orchestrator/mod.rs").label(), "general");
        assert_eq!(classify("milp/factor.rs").label(), "deterministic+hot");
    }

    #[test]
    fn cfg_test_mod_region() {
        let src = "fn lib() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}\n";
        let scan = FileScan::scan(src);
        let t = test_regions(&scan);
        assert_eq!(t, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn test_fn_region() {
        let src = "#[test]\nfn check() {\n    body();\n}\nfn lib() {}\n";
        let scan = FileScan::scan(src);
        let t = test_regions(&scan);
        assert_eq!(t, vec![true, true, true, true, false]);
    }

    #[test]
    fn braceless_cfg_test_item() {
        let src = "#[cfg(test)]\nuse std::collections::HashSet;\nfn lib() {}\n";
        let scan = FileScan::scan(src);
        let t = test_regions(&scan);
        assert_eq!(t, vec![true, true, false]);
    }

    #[test]
    fn braces_in_strings_do_not_desync() {
        let src = concat!(
            "#[cfg(test)]\nmod tests {\n    const S: &str = \"}}}\";\n",
            "    fn t() {}\n}\nfn lib() {}\n"
        );
        let scan = FileScan::scan(src);
        let t = test_regions(&scan);
        assert!(!t[5], "lib fn after the test mod must not be a test region");
        assert!(t[2] && t[4]);
    }
}
