//! Diagnostics: rule identities, spans, and rendering.

use super::zones::ZoneSet;
use std::fmt;

/// Stable rule identifiers. IDs are the public contract: they appear in
/// diagnostics, suppression comments, and the committed baseline, so they
/// must never be renumbered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum RuleId {
    /// `HashMap`/`HashSet`/`RandomState` in a deterministic zone
    /// (iteration order is randomized per-process).
    D001,
    /// `Instant::now` / `SystemTime` / `thread::current().id()` in a
    /// deterministic zone (wall-clock and thread identity are
    /// run-dependent).
    D002,
    /// Unseeded / entropy-based RNG construction outside `util::rng`.
    D003,
    /// `Ordering::Relaxed`/`Acquire`/`Release`/`AcqRel` without an adjacent
    /// `// ordering:` justification comment.
    A001,
    /// Bare `==`/`!=` against a float literal outside tolerance helpers.
    F001,
    /// `unwrap()` / `panic!` / `unreachable!` / `todo!` / `unimplemented!`
    /// in non-test library code (ratcheted; `expect("invariant")` is the
    /// sanctioned replacement).
    P001,
    /// Malformed `pallas-lint:` directive (unknown rule, missing reason).
    L001,
}

pub const ALL_RULES: &[RuleId] = &[
    RuleId::D001,
    RuleId::D002,
    RuleId::D003,
    RuleId::A001,
    RuleId::F001,
    RuleId::P001,
    RuleId::L001,
];

impl RuleId {
    pub fn as_str(&self) -> &'static str {
        match self {
            RuleId::D001 => "D001",
            RuleId::D002 => "D002",
            RuleId::D003 => "D003",
            RuleId::A001 => "A001",
            RuleId::F001 => "F001",
            RuleId::P001 => "P001",
            RuleId::L001 => "L001",
        }
    }

    pub fn parse(s: &str) -> Option<RuleId> {
        ALL_RULES.iter().copied().find(|r| r.as_str() == s)
    }

    pub fn title(&self) -> &'static str {
        match self {
            RuleId::D001 => "hash-order nondeterminism in deterministic zone",
            RuleId::D002 => "wall-clock / thread-identity read in deterministic zone",
            RuleId::D003 => "unseeded RNG construction outside util::rng",
            RuleId::A001 => "atomic ordering without `// ordering:` justification",
            RuleId::F001 => "bare float comparison against a literal",
            RuleId::P001 => "panic-path in library code (unwrap/panic!/unreachable!)",
            RuleId::L001 => "malformed pallas-lint directive",
        }
    }

    /// Ratchetable rules may carry frozen debt in `analysis/baseline.json`.
    /// D-rules are zero-tolerance: a violation in the deterministic zone is
    /// either fixed or carries a reasoned inline allow — never baselined
    /// (the whole point of the zone is that the invariant holds *now*).
    pub fn ratchetable(&self) -> bool {
        matches!(self, RuleId::A001 | RuleId::F001 | RuleId::P001)
    }
}

impl fmt::Display for RuleId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One violation, with an exact source span.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub rule: RuleId,
    /// Path relative to the scanned root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// 0-based column of the offending token.
    pub col: usize,
    /// Length (chars) of the offending token.
    pub len: usize,
    pub message: String,
    /// The raw source line, for caret rendering.
    pub line_text: String,
    pub zone: ZoneSet,
}

impl Diagnostic {
    /// `file:line:col: RULE message`, then the source line with a caret
    /// underline — span-accurate so editors and humans land on the token.
    pub fn render(&self) -> String {
        let mut out = format!(
            "{}:{}:{}: {} [{}] {}\n",
            self.file,
            self.line,
            self.col + 1,
            self.rule,
            self.zone.label(),
            self.message
        );
        out.push_str(&format!("    {}\n", self.line_text));
        let mut caret = String::from("    ");
        for ch in self.line_text.chars().take(self.col) {
            caret.push(if ch == '\t' { '\t' } else { ' ' });
        }
        caret.push_str(&"^".repeat(self.len.max(1)));
        out.push_str(&caret);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_id_round_trip() {
        for r in ALL_RULES {
            assert_eq!(RuleId::parse(r.as_str()), Some(*r));
        }
        assert_eq!(RuleId::parse("D999"), None);
    }

    #[test]
    fn d_rules_are_not_ratchetable() {
        assert!(!RuleId::D001.ratchetable());
        assert!(!RuleId::D002.ratchetable());
        assert!(!RuleId::D003.ratchetable());
        assert!(RuleId::P001.ratchetable());
        assert!(RuleId::F001.ratchetable());
        assert!(RuleId::A001.ratchetable());
    }

    #[test]
    fn render_points_at_token() {
        let d = Diagnostic {
            rule: RuleId::D001,
            file: "sim/engine.rs".into(),
            line: 10,
            col: 8,
            len: 7,
            message: "HashMap in deterministic zone".into(),
            line_text: "    let HashMap = 1;".into(),
            zone: ZoneSet {
                deterministic: true,
                hot: true,
            },
        };
        let r = d.render();
        assert!(r.starts_with("sim/engine.rs:10:9: D001 [deterministic+hot]"));
        let caret_line = r.lines().last().expect("caret line present");
        assert_eq!(caret_line.find('^'), Some(8 + 4));
        assert!(caret_line.ends_with("^^^^^^^"));
    }
}
