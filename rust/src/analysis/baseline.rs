//! The ratchet: frozen per-(rule, file) debt counts in
//! `analysis/baseline.json`.
//!
//! Check mode compares the current violation counts against the committed
//! baseline: counts above it **fail**, counts at it pass (frozen debt),
//! counts below it pass with a shrink note — run `lint --update-baseline`
//! to commit the improvement so the debt can never grow back. Only
//! ratchetable rules ([`RuleId::ratchetable`]) may appear in the baseline;
//! D-rules are zero-tolerance and a baseline file naming one is rejected
//! outright (tampering with the file must not re-open the determinism
//! invariants).

use super::diag::{Diagnostic, RuleId};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Frozen debt: rule id → file → allowed count. BTreeMaps keep the JSON
/// serialization deterministic so baseline diffs are reviewable.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Baseline {
    counts: BTreeMap<String, BTreeMap<String, u64>>,
}

impl Baseline {
    pub fn empty() -> Baseline {
        Baseline::default()
    }

    pub fn parse(text: &str) -> anyhow::Result<Baseline> {
        let doc = Json::parse(text).map_err(|e| anyhow::anyhow!("baseline: {e}"))?;
        let obj = doc
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("baseline: top level must be an object"))?;
        let mut counts = BTreeMap::new();
        let rules = obj
            .get("counts")
            .and_then(|c| c.as_obj())
            .ok_or_else(|| anyhow::anyhow!("baseline: missing \"counts\" object"))?;
        for (rule_s, files) in rules {
            let rule = RuleId::parse(rule_s)
                .ok_or_else(|| anyhow::anyhow!("baseline: unknown rule '{rule_s}'"))?;
            if !rule.ratchetable() {
                anyhow::bail!(
                    "baseline: rule {rule} is zero-tolerance and may not carry frozen debt — \
                     fix the violation or add an inline allow with a reason"
                );
            }
            let files_obj = files
                .as_obj()
                .ok_or_else(|| anyhow::anyhow!("baseline: counts.{rule_s} must be an object"))?;
            let mut per_file = BTreeMap::new();
            for (file, n) in files_obj {
                let n = n
                    .as_u64()
                    .ok_or_else(|| anyhow::anyhow!("baseline: {rule_s}.{file} not a count"))?;
                if n > 0 {
                    per_file.insert(file.clone(), n);
                }
            }
            if !per_file.is_empty() {
                counts.insert(rule_s.clone(), per_file);
            }
        }
        Ok(Baseline { counts })
    }

    /// Build a baseline from current violations (ratchetable rules only —
    /// zero-tolerance rules are deliberately dropped so `--update-baseline`
    /// can never launder a D-rule violation into frozen debt).
    pub fn from_violations(diags: &[Diagnostic]) -> Baseline {
        let mut counts: BTreeMap<String, BTreeMap<String, u64>> = BTreeMap::new();
        for d in diags {
            if d.rule.ratchetable() {
                *counts
                    .entry(d.rule.as_str().to_string())
                    .or_default()
                    .entry(d.file.clone())
                    .or_insert(0) += 1;
            }
        }
        Baseline { counts }
    }

    pub fn allowed(&self, rule: RuleId, file: &str) -> u64 {
        self.counts
            .get(rule.as_str())
            .and_then(|m| m.get(file))
            .copied()
            .unwrap_or(0)
    }

    /// Total frozen debt per rule (for the summary line).
    pub fn total(&self, rule: RuleId) -> u64 {
        self.counts
            .get(rule.as_str())
            .map(|m| m.values().sum())
            .unwrap_or(0)
    }

    pub fn to_json_string(&self) -> String {
        let mut rules = BTreeMap::new();
        for (rule, files) in &self.counts {
            let mut obj = BTreeMap::new();
            for (file, n) in files {
                obj.insert(file.clone(), Json::num(*n as f64));
            }
            rules.insert(rule.clone(), Json::Obj(obj));
        }
        let doc = Json::Obj(BTreeMap::from([
            ("version".to_string(), Json::num(1.0)),
            ("counts".to_string(), Json::Obj(rules)),
        ]));
        // to_string_pretty already ends with a newline.
        doc.to_string_pretty()
    }
}

/// One (rule, file) group that exceeded its frozen allowance.
#[derive(Debug)]
pub struct FailureGroup {
    pub rule: RuleId,
    pub file: String,
    pub found: u64,
    pub allowed: u64,
    pub diags: Vec<Diagnostic>,
}

/// Outcome of diffing current violations against the baseline.
#[derive(Debug, Default)]
pub struct RatchetOutcome {
    /// Groups over their allowance (or zero-tolerance hits). Non-empty ⇒
    /// the lint run fails.
    pub failures: Vec<FailureGroup>,
    /// Violations absorbed by frozen debt.
    pub frozen: u64,
    /// `(rule, file, frozen, current)` where current < frozen — improvements
    /// waiting for `--update-baseline` to lock them in.
    pub shrink: Vec<(String, String, u64, u64)>,
}

/// Diff current violations against the frozen baseline.
pub fn ratchet(diags: &[Diagnostic], base: &Baseline) -> RatchetOutcome {
    let mut by_group: BTreeMap<(String, RuleId), Vec<Diagnostic>> = BTreeMap::new();
    for d in diags {
        by_group
            .entry((d.file.clone(), d.rule))
            .or_default()
            .push(d.clone());
    }

    let mut out = RatchetOutcome::default();
    for ((file, rule), group) in by_group {
        let found = group.len() as u64;
        let allowed = if rule.ratchetable() {
            base.allowed(rule, &file)
        } else {
            0
        };
        if found > allowed {
            out.frozen += allowed;
            out.failures.push(FailureGroup {
                rule,
                file,
                found,
                allowed,
                diags: group,
            });
        } else {
            out.frozen += found;
            if found < allowed {
                out.shrink
                    .push((rule.as_str().to_string(), file, allowed, found));
            }
        }
    }
    // Baseline entries for files that now lint clean also shrink.
    for (rule_s, files) in &base.counts {
        let rule = RuleId::parse(rule_s).expect("invariant: parse() rejected unknown rules");
        for (file, &allowed) in files {
            let still_present = diags
                .iter()
                .any(|d| d.rule == rule && d.file == *file);
            if !still_present {
                out.shrink
                    .push((rule_s.clone(), file.clone(), allowed, 0));
            }
        }
    }
    out.shrink.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::zones::ZoneSet;

    fn diag(rule: RuleId, file: &str, line: usize) -> Diagnostic {
        Diagnostic {
            rule,
            file: file.to_string(),
            line,
            col: 0,
            len: 1,
            message: String::new(),
            line_text: String::new(),
            zone: ZoneSet::default(),
        }
    }

    #[test]
    fn round_trip() {
        let diags = vec![
            diag(RuleId::P001, "a.rs", 1),
            diag(RuleId::P001, "a.rs", 2),
            diag(RuleId::F001, "b.rs", 3),
        ];
        let base = Baseline::from_violations(&diags);
        let text = base.to_json_string();
        let re = Baseline::parse(&text).expect("own output must parse");
        assert_eq!(re, base);
        assert_eq!(re.allowed(RuleId::P001, "a.rs"), 2);
        assert_eq!(re.total(RuleId::P001), 2);
        assert_eq!(re.allowed(RuleId::F001, "b.rs"), 1);
    }

    #[test]
    fn d_rules_never_enter_a_baseline() {
        let diags = vec![diag(RuleId::D001, "sim/engine.rs", 1)];
        let base = Baseline::from_violations(&diags);
        assert_eq!(base, Baseline::empty());
        // And a hand-edited baseline naming a D-rule is rejected.
        let doc = r#"{"version": 1, "counts": {"D001": {"sim/engine.rs": 1}}}"#;
        assert!(Baseline::parse(doc).is_err());
    }

    #[test]
    fn ratchet_freezes_existing_fails_new() {
        let base = Baseline::from_violations(&[
            diag(RuleId::P001, "a.rs", 1),
            diag(RuleId::P001, "a.rs", 2),
        ]);
        // Same count: frozen, no failure.
        let now = vec![diag(RuleId::P001, "a.rs", 5), diag(RuleId::P001, "a.rs", 9)];
        let out = ratchet(&now, &base);
        assert!(out.failures.is_empty());
        assert_eq!(out.frozen, 2);

        // One more: the group fails with the delta visible.
        let more = vec![
            diag(RuleId::P001, "a.rs", 5),
            diag(RuleId::P001, "a.rs", 9),
            diag(RuleId::P001, "a.rs", 11),
        ];
        let out = ratchet(&more, &base);
        assert_eq!(out.failures.len(), 1);
        assert_eq!((out.failures[0].found, out.failures[0].allowed), (3, 2));
    }

    #[test]
    fn ratchet_shrinks_on_improvement() {
        let base = Baseline::from_violations(&[
            diag(RuleId::P001, "a.rs", 1),
            diag(RuleId::P001, "a.rs", 2),
            diag(RuleId::F001, "b.rs", 1),
        ]);
        let now = vec![diag(RuleId::P001, "a.rs", 1)];
        let out = ratchet(&now, &base);
        assert!(out.failures.is_empty());
        assert_eq!(
            out.shrink,
            vec![
                ("F001".to_string(), "b.rs".to_string(), 1, 0),
                ("P001".to_string(), "a.rs".to_string(), 2, 1),
            ]
        );
    }

    #[test]
    fn zero_tolerance_rules_fail_regardless() {
        let now = vec![diag(RuleId::D002, "sim/engine.rs", 7)];
        let out = ratchet(&now, &Baseline::empty());
        assert_eq!(out.failures.len(), 1);
        assert_eq!(out.failures[0].allowed, 0);
    }
}
