//! # hetserve
//!
//! Cost-efficient LLM serving over heterogeneous GPUs — a full reproduction
//! of *"Demystifying Cost-Efficiency in LLM Serving over Heterogeneous
//! GPUs"* (ICML 2025) as a rust coordinator + JAX/Pallas AOT compute stack.
//!
//! The crate is organised bottom-up:
//! * [`util`] — offline substrates (json, cli, rng, pool, stats, bench, proptest)
//! * [`catalog`] — GPU types, Table 1 specs, interconnects
//! * [`workload`] — the nine workload types, Table 4 traces, synthesizer;
//!   plus demand drift: time-varying mix schedules, non-stationary trace
//!   synthesis, and the online mixture estimator; `workload::stream` is
//!   the O(1)-memory lazy arrival generator the materializer now wraps
//! * [`cloud`] — availability snapshots (Table 3), market simulator, costs,
//!   and the event streams: supply-only market events and the unified
//!   world events carrying a demand channel; `cloud::faults` is the
//!   seeded fault injector — preemption/crash storm profiles compiled
//!   into replayable kill schedules and market-view dents so the
//!   orchestrator and the simulators see one consistent chaos
//! * [`perf_model`] — analytical roofline model replacing real-GPU profiling
//! * [`profiler`] — `h_{c,w}` throughput tables for the scheduler
//! * [`milp`] — from-scratch MILP solver: a factorized revised simplex
//!   (LU basis + product-form eta updates with periodic refactorisation,
//!   dual steepest-edge pricing) behind a bounded-variable arena with
//!   dual-simplex warm starts, basis snapshots that crash-warm the next
//!   structurally identical solve, and a deterministic parallel branch &
//!   bound whose branches are pure bound tightenings; the legacy dense
//!   eliminated-tableau arena survives as the A/B reference core (see
//!   `milp/README.md`)
//! * [`sched`] — the paper's scheduling algorithm (§4.3, App D–G), topped
//!   by [`sched::planner`]: the unified planning surface — one `Planner`
//!   trait and `PlanRequest`/`PlanReport` contract for every strategy,
//!   with the stateful `PlannerSession` carrying warm solver state
//!   (incumbent plan + per-oracle root bases for both the exact-MILP and
//!   knapsack-rounding paths) across bisection iterates, replan epochs,
//!   and baseline sweeps
//! * [`baselines`] — homogeneous / HexGen-like / ablation planners, all
//!   `sched::planner::Planner` impls behind one registry
//! * [`orchestrator`] — online replanning over the drifting *world*
//!   (supply and demand): plan-diff engine, two-axis drift thresholds,
//!   assignment-LP fast path, incremental/escalating replanner composed
//!   over a `PlannerSession`, epoch timeline; planner deadlines feed a
//!   stepwise degradation ladder (repair-only → shed → emergency
//!   homogeneous) with hysteresis (see `orchestrator/README.md`)
//! * [`sim`] — discrete-event cluster simulator executing serving plans,
//!   including time-varying timelines with mid-trace plan transitions and
//!   the closed demand loop (estimator-driven replanning); `sim::engine`
//!   is the sharded million-request core: per-replica queues advance in
//!   parallel on the threadpool, fed by streamed arrivals, bit-identical
//!   at any thread count (see `sim/README.md`)
//! * [`telemetry`] — unified observability: a global metric registry
//!   (atomic counters / gauges / log-bucketed histograms), RAII nesting
//!   spans with thread-aware buffering, Chrome trace-event export
//!   (`--trace-out`, perfetto-viewable), and the `TelemetrySnapshot`
//!   report merged into command output
//! * [`runtime`] — PJRT engine: loads AOT HLO artifacts, paged KV cache
//! * [`coordinator`] — the real serving path: router, batcher, workers
//! * [`analysis`] — `pallas-lint`, the in-repo invariant analyzer: a
//!   hand-rolled comment/string-aware Rust scanner + rule engine that
//!   enforces the determinism-zone, atomic-ordering, and numerical-hygiene
//!   invariants by construction (D/A/F/P rule families, inline reasoned
//!   allows, ratcheting `analysis/baseline.json`); runs as the `lint`
//!   subcommand and as a CI gate (see `src/analysis/README.md`)

// Lint policy: CI runs `cargo clippy --all-targets -- -D warnings`. The
// numeric kernels (simplex tableau, roofline model, market walks) index
// several parallel arrays per loop, where iterator rewrites obscure the
// math without removing a bounds check — those two pedantic-leaning style
// lints are opted out crate-wide instead of case by case.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_range_contains)]

pub mod analysis;
pub mod baselines;
pub mod catalog;
pub mod cloud;
pub mod coordinator;
pub mod metrics;
pub mod milp;
pub mod orchestrator;
pub mod perf_model;
pub mod profiler;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod telemetry;
pub mod util;
pub mod workload;
