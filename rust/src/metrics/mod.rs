//! Serving metrics: latency recording, percentile reports, and windowed
//! throughput — shared by the simulator, the real serving coordinator, and
//! every benchmark harness.

use crate::util::stats::{paper_percentile_grid, percentile};

/// Collects per-request latencies and completion times.
#[derive(Clone, Debug, Default)]
pub struct LatencyRecorder {
    /// (completion_time_s, latency_s) pairs.
    samples: Vec<(f64, f64)>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, completion_s: f64, latency_s: f64) {
        self.samples.push((completion_s, latency_s));
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn latencies(&self) -> Vec<f64> {
        self.samples.iter().map(|&(_, l)| l).collect()
    }

    /// Time of the last completion (the makespan when arrivals are batched).
    pub fn makespan(&self) -> f64 {
        self.samples.iter().map(|&(t, _)| t).fold(0.0, f64::max)
    }

    /// Overall throughput: completions / makespan.
    pub fn throughput_rps(&self) -> f64 {
        let span = self.makespan();
        if span > 0.0 {
            self.count() as f64 / span
        } else {
            0.0
        }
    }

    /// Latency percentile (p in [0,100]).
    pub fn latency_percentile(&self, p: f64) -> f64 {
        let mut v = self.latencies();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile(&v, p)
    }

    /// The paper's p5..p100 latency grid.
    pub fn percentile_grid(&self) -> Vec<(f64, f64)> {
        let mut v = self.latencies();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        paper_percentile_grid()
            .into_iter()
            .map(|p| (p, percentile(&v, p)))
            .collect()
    }

    pub fn merge(&mut self, other: &LatencyRecorder) {
        self.samples.extend_from_slice(&other.samples);
    }

    /// Fraction of recorded requests whose latency is within `slo_s` (SLO
    /// attainment). 1.0 for an empty recorder — no request missed the SLO.
    pub fn slo_attainment(&self, slo_s: f64) -> f64 {
        if self.samples.is_empty() {
            return 1.0;
        }
        let within = self
            .samples
            .iter()
            .filter(|&&(_, l)| l <= slo_s)
            .count();
        within as f64 / self.samples.len() as f64
    }
}

/// Tracks busy time for utilization reporting.
#[derive(Clone, Debug, Default)]
pub struct BusyTracker {
    pub busy_s: f64,
    pub last_event_s: f64,
}

impl BusyTracker {
    pub fn add_busy(&mut self, start_s: f64, duration_s: f64) {
        self.busy_s += duration_s;
        self.last_event_s = self.last_event_s.max(start_s + duration_s);
    }

    pub fn utilization(&self, horizon_s: f64) -> f64 {
        if horizon_s > 0.0 {
            (self.busy_s / horizon_s).min(1.0)
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recorder_basics() {
        let mut r = LatencyRecorder::new();
        for i in 1..=10 {
            r.record(i as f64, i as f64 * 0.1);
        }
        assert_eq!(r.count(), 10);
        assert_eq!(r.makespan(), 10.0);
        assert!((r.throughput_rps() - 1.0).abs() < 1e-12);
        assert!((r.latency_percentile(100.0) - 1.0).abs() < 1e-12);
        let grid = r.percentile_grid();
        assert_eq!(grid.len(), 20);
        assert_eq!(grid[19].0, 100.0);
    }

    #[test]
    fn slo_attainment_counts_within_threshold() {
        let mut r = LatencyRecorder::new();
        for i in 1..=10 {
            r.record(i as f64, i as f64); // latencies 1..=10
        }
        assert!((r.slo_attainment(5.0) - 0.5).abs() < 1e-12);
        assert_eq!(r.slo_attainment(0.5), 0.0);
        assert_eq!(r.slo_attainment(100.0), 1.0);
        assert_eq!(LatencyRecorder::new().slo_attainment(1.0), 1.0);
    }

    #[test]
    fn merge_combines() {
        let mut a = LatencyRecorder::new();
        a.record(1.0, 0.5);
        let mut b = LatencyRecorder::new();
        b.record(2.0, 0.7);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.makespan(), 2.0);
    }

    #[test]
    fn busy_tracker_utilization() {
        let mut t = BusyTracker::default();
        t.add_busy(0.0, 5.0);
        t.add_busy(6.0, 2.0);
        assert!((t.utilization(10.0) - 0.7).abs() < 1e-12);
        assert_eq!(t.utilization(0.0), 0.0);
    }
}
