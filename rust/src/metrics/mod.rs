//! Serving metrics: latency recording, percentile reports, and windowed
//! throughput — shared by the simulator, the real serving coordinator, and
//! every benchmark harness.

use crate::util::rng::Xoshiro256;
use crate::util::stats::{paper_percentile_grid, percentile};
use std::sync::{Arc, Mutex};

/// Reservoir state for the fixed-memory recording mode (Algorithm R):
/// every recorded sample is kept until `cap` is reached, after which each
/// new sample replaces a stored one with probability `cap / seen` — the
/// stored pool stays a uniform sample of everything ever recorded.
#[derive(Clone, Debug)]
struct Reservoir {
    cap: usize,
    rng: Xoshiro256,
}

/// Collects per-request latencies and completion times.
///
/// Two modes:
/// * **exact** ([`LatencyRecorder::new`]) — every sample stored; counts,
///   makespan, and percentiles are exact. O(n) memory.
/// * **bounded** ([`LatencyRecorder::bounded`]) — at most `cap` samples
///   stored in a uniform reservoir; `count` and `makespan` stay *exact*
///   (tracked separately), percentiles and SLO attainment are reservoir
///   estimates. O(cap) memory — what million-request simulation runs use.
#[derive(Debug, Default)]
pub struct LatencyRecorder {
    /// (completion_time_s, latency_s) pairs (the reservoir, in bounded mode).
    samples: Vec<(f64, f64)>,
    /// Lazily-built ascending latency view shared by every percentile
    /// query. Percentile callers used to re-sort the full sample vector on
    /// *every* call (the per-epoch reporting loop made that quadratic);
    /// now the first query after a mutation sorts once and the rest reuse
    /// the cached view. Mutations (`record`/`merge`) invalidate it.
    sorted: Mutex<Option<Arc<Vec<f64>>>>,
    /// `Some` in bounded mode.
    reservoir: Option<Reservoir>,
    /// Total samples ever recorded (== `samples.len()` in exact mode).
    seen: usize,
    /// Requests that never completed — shed by admission control or
    /// dropped after retry exhaustion. Tracked exactly (no reservoir) and
    /// counted as SLO misses by [`Self::slo_attainment`], which therefore
    /// reports *goodput*, not completion-conditional attainment.
    dropped: usize,
    /// Exact max completion time across every recorded sample.
    max_completion_s: f64,
}

impl Clone for LatencyRecorder {
    fn clone(&self) -> Self {
        Self {
            samples: self.samples.clone(),
            sorted: Mutex::new(self.sorted.lock().unwrap().clone()),
            reservoir: self.reservoir.clone(),
            seen: self.seen,
            dropped: self.dropped,
            max_completion_s: self.max_completion_s,
        }
    }
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fixed-memory recorder: keeps a deterministic (seeded) uniform
    /// reservoir of at most `cap` samples.
    pub fn bounded(cap: usize, seed: u64) -> Self {
        Self::bounded_from_rng(cap, Xoshiro256::seed_from_u64(seed))
    }

    /// Like [`Self::bounded`] but with a caller-supplied generator — the
    /// sharded simulator hands each shard's recorder its own
    /// [`Xoshiro256::substream`] so reservoirs stay independent *and*
    /// deterministic from one seed.
    pub fn bounded_from_rng(cap: usize, rng: Xoshiro256) -> Self {
        assert!(cap > 0, "bounded recorder needs a positive capacity");
        Self {
            samples: Vec::new(),
            sorted: Mutex::new(None),
            reservoir: Some(Reservoir { cap, rng }),
            seen: 0,
            dropped: 0,
            max_completion_s: 0.0,
        }
    }

    /// Whether this recorder subsamples (bounded mode).
    pub fn is_bounded(&self) -> bool {
        self.reservoir.is_some()
    }

    /// Stored-sample count (== `count()` in exact mode, ≤ cap in bounded).
    pub fn stored(&self) -> usize {
        self.samples.len()
    }

    pub fn record(&mut self, completion_s: f64, latency_s: f64) {
        self.seen += 1;
        self.max_completion_s = self.max_completion_s.max(completion_s);
        match &mut self.reservoir {
            None => self.samples.push((completion_s, latency_s)),
            Some(r) => {
                if self.samples.len() < r.cap {
                    self.samples.push((completion_s, latency_s));
                } else {
                    // Algorithm R: keep with probability cap/seen.
                    let j = r.rng.next_below(self.seen as u64) as usize;
                    if j < r.cap {
                        self.samples[j] = (completion_s, latency_s);
                    } else {
                        return; // stored pool untouched: cache stays valid
                    }
                }
            }
        }
        *self.sorted.get_mut().unwrap() = None;
    }

    /// Total samples ever recorded (exact in both modes). Dropped requests
    /// are *not* counted here — they never completed.
    pub fn count(&self) -> usize {
        self.seen
    }

    /// Record `n` requests that will never complete (admission shed or
    /// retry exhaustion). They join the SLO denominator as misses.
    pub fn record_dropped(&mut self, n: usize) {
        self.dropped += n;
    }

    /// Requests recorded as dropped (exact in both modes).
    pub fn dropped(&self) -> usize {
        self.dropped
    }

    pub fn latencies(&self) -> Vec<f64> {
        self.samples.iter().map(|&(_, l)| l).collect()
    }

    /// Time of the last completion (the makespan when arrivals are batched).
    /// Exact in both modes.
    pub fn makespan(&self) -> f64 {
        self.max_completion_s
    }

    /// Overall throughput: completions / makespan.
    pub fn throughput_rps(&self) -> f64 {
        let span = self.makespan();
        if span > 0.0 {
            self.count() as f64 / span
        } else {
            0.0
        }
    }

    /// The sorted latency view behind every percentile query: built on the
    /// first call after a mutation, shared (via `Arc`) afterwards.
    fn sorted_latencies(&self) -> Arc<Vec<f64>> {
        let mut guard = self.sorted.lock().unwrap();
        if let Some(v) = guard.as_ref() {
            return Arc::clone(v);
        }
        let mut v = self.latencies();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let v = Arc::new(v);
        *guard = Some(Arc::clone(&v));
        v
    }

    /// Latency percentile (p in [0,100]).
    pub fn latency_percentile(&self, p: f64) -> f64 {
        percentile(self.sorted_latencies().as_slice(), p)
    }

    /// The paper's p5..p100 latency grid.
    pub fn percentile_grid(&self) -> Vec<(f64, f64)> {
        let v = self.sorted_latencies();
        paper_percentile_grid()
            .into_iter()
            .map(|p| (p, percentile(v.as_slice(), p)))
            .collect()
    }

    /// Merge another recorder into this one. Counts and makespan merge
    /// exactly in every mode. In bounded mode the stored pools are
    /// concatenated and, if over capacity, deterministically resampled
    /// down to `cap` — an approximation (the union is resampled uniformly
    /// over *stored* samples, not weighted by the true per-recorder
    /// counts), adequate for the percentile reporting it feeds.
    pub fn merge(&mut self, other: &LatencyRecorder) {
        self.seen += other.seen;
        self.dropped += other.dropped;
        self.max_completion_s = self.max_completion_s.max(other.max_completion_s);
        self.samples.extend_from_slice(&other.samples);
        if let Some(r) = &mut self.reservoir {
            if self.samples.len() > r.cap {
                r.rng.shuffle(&mut self.samples);
                self.samples.truncate(r.cap);
            }
        }
        *self.sorted.get_mut().unwrap() = None;
    }

    /// *Goodput*: the fraction of all recorded outcomes — completions AND
    /// drops — whose latency is within `slo_s`. A dropped request can
    /// never meet the SLO, so shedding and retry exhaustion lower this
    /// number instead of flattering it. 1.0 for an empty recorder (no
    /// request missed), 0.0 when everything was dropped. The
    /// within-fraction over completions is a reservoir estimate in
    /// bounded mode; the drop weighting is exact.
    pub fn slo_attainment(&self, slo_s: f64) -> f64 {
        let total = self.seen + self.dropped;
        if total == 0 {
            return 1.0;
        }
        if self.samples.is_empty() {
            // Nothing completed: every outcome is a dropped miss.
            return 0.0;
        }
        let within = self
            .samples
            .iter()
            .filter(|&&(_, l)| l <= slo_s)
            .count();
        let within_frac = within as f64 / self.samples.len() as f64;
        within_frac * self.seen as f64 / total as f64
    }
}

/// Tracks busy time for utilization reporting.
#[derive(Clone, Debug, Default)]
pub struct BusyTracker {
    pub busy_s: f64,
    pub last_event_s: f64,
}

impl BusyTracker {
    pub fn add_busy(&mut self, start_s: f64, duration_s: f64) {
        self.busy_s += duration_s;
        self.last_event_s = self.last_event_s.max(start_s + duration_s);
    }

    pub fn utilization(&self, horizon_s: f64) -> f64 {
        if horizon_s > 0.0 {
            (self.busy_s / horizon_s).min(1.0)
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recorder_basics() {
        let mut r = LatencyRecorder::new();
        for i in 1..=10 {
            r.record(i as f64, i as f64 * 0.1);
        }
        assert_eq!(r.count(), 10);
        assert_eq!(r.makespan(), 10.0);
        assert!((r.throughput_rps() - 1.0).abs() < 1e-12);
        assert!((r.latency_percentile(100.0) - 1.0).abs() < 1e-12);
        let grid = r.percentile_grid();
        assert_eq!(grid.len(), 20);
        assert_eq!(grid[19].0, 100.0);
    }

    #[test]
    fn slo_attainment_counts_within_threshold() {
        let mut r = LatencyRecorder::new();
        for i in 1..=10 {
            r.record(i as f64, i as f64); // latencies 1..=10
        }
        assert!((r.slo_attainment(5.0) - 0.5).abs() < 1e-12);
        assert_eq!(r.slo_attainment(0.5), 0.0);
        assert_eq!(r.slo_attainment(100.0), 1.0);
        assert_eq!(LatencyRecorder::new().slo_attainment(1.0), 1.0);
    }

    #[test]
    fn dropped_requests_count_against_goodput() {
        let mut r = LatencyRecorder::new();
        for i in 1..=8 {
            r.record(i as f64, 1.0); // all within a 2s SLO
        }
        assert_eq!(r.slo_attainment(2.0), 1.0);
        r.record_dropped(2);
        assert_eq!(r.dropped(), 2);
        assert_eq!(r.count(), 8, "drops never join the completion count");
        assert!((r.slo_attainment(2.0) - 0.8).abs() < 1e-12);
        let mut other = LatencyRecorder::new();
        other.record_dropped(10);
        assert_eq!(other.slo_attainment(1.0), 0.0, "all-dropped is zero goodput");
        r.merge(&other);
        assert_eq!(r.dropped(), 12);
        assert!((r.slo_attainment(2.0) - 0.4).abs() < 1e-12);
        assert_eq!(LatencyRecorder::new().slo_attainment(1.0), 1.0);
    }

    #[test]
    fn merge_combines() {
        let mut a = LatencyRecorder::new();
        a.record(1.0, 0.5);
        let mut b = LatencyRecorder::new();
        b.record(2.0, 0.7);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.makespan(), 2.0);
    }

    #[test]
    fn cached_percentiles_match_naive_resort() {
        // The cached sorted view must be observationally identical to the
        // old sort-on-every-call behaviour, including across mutations
        // that invalidate it.
        let naive = |r: &LatencyRecorder, p: f64| {
            let mut v = r.latencies();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            percentile(&v, p)
        };
        let mut r = LatencyRecorder::new();
        // Deliberately unsorted arrivals, with duplicates.
        for (i, &l) in [5.0, 1.0, 3.0, 3.0, 9.0, 2.0, 7.0].iter().enumerate() {
            r.record(i as f64, l);
        }
        for p in [0.0, 5.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(r.latency_percentile(p), naive(&r, p), "p{p}");
            // Second call answers from the cache — still identical.
            assert_eq!(r.latency_percentile(p), naive(&r, p), "cached p{p}");
        }
        // Mutating after a cached query must invalidate the view.
        r.record(100.0, 0.5);
        assert_eq!(r.latency_percentile(0.0), 0.5);
        let mut other = LatencyRecorder::new();
        other.record(101.0, 42.0);
        r.merge(&other);
        assert_eq!(r.latency_percentile(100.0), 42.0);
        // A clone carries consistent state too.
        let c = r.clone();
        assert_eq!(c.latency_percentile(50.0), naive(&c, 50.0));
        for (p, v) in r.percentile_grid() {
            assert_eq!(v, naive(&r, p), "grid p{p}");
        }
    }

    #[test]
    fn bounded_reservoir_tracks_exact_percentiles() {
        // The satellite contract: a 4096-sample reservoir over a 50k-sample
        // heavy-tailed stream must agree with exact percentiles within a
        // few percent, while counts and makespan stay *exact*.
        let mut rng = Xoshiro256::seed_from_u64(0xB0B);
        let mut exact = LatencyRecorder::new();
        let mut bounded = LatencyRecorder::bounded(4096, 0xCAFE);
        let n = 50_000;
        for i in 0..n {
            let latency = rng.lognormal(1.0, 0.6);
            let t = i as f64 * 0.05;
            exact.record(t, latency);
            bounded.record(t, latency);
        }
        assert!(bounded.is_bounded() && !exact.is_bounded());
        assert_eq!(bounded.count(), n);
        assert_eq!(bounded.stored(), 4096);
        assert_eq!(bounded.makespan(), exact.makespan());
        for p in [50.0, 90.0, 99.0] {
            let (e, b) = (exact.latency_percentile(p), bounded.latency_percentile(p));
            // ~3% sampling error expected at p90 for a 4096 reservoir;
            // p99 is noisier — 10% is a comfortable determinism-safe bound.
            assert!(
                (b / e - 1.0).abs() < 0.10,
                "p{p}: bounded {b} vs exact {e}"
            );
        }
        let slo = exact.latency_percentile(80.0);
        assert!(
            (bounded.slo_attainment(slo) - exact.slo_attainment(slo)).abs() < 0.02,
            "slo estimate {} vs exact {}",
            bounded.slo_attainment(slo),
            exact.slo_attainment(slo)
        );
        // Deterministic from the seed.
        let mut again = LatencyRecorder::bounded(4096, 0xCAFE);
        let mut rng2 = Xoshiro256::seed_from_u64(0xB0B);
        for i in 0..n {
            again.record(i as f64 * 0.05, rng2.lognormal(1.0, 0.6));
        }
        assert_eq!(again.latencies(), bounded.latencies());
    }

    #[test]
    fn bounded_merge_keeps_exact_counts_and_capacity() {
        let mut a = LatencyRecorder::bounded(100, 1);
        let mut b = LatencyRecorder::bounded(100, 2);
        for i in 0..500 {
            a.record(i as f64, 1.0 + (i % 7) as f64);
            b.record(1000.0 + i as f64, 2.0 + (i % 5) as f64);
        }
        a.merge(&b);
        assert_eq!(a.count(), 1000);
        assert_eq!(a.stored(), 100);
        assert_eq!(a.makespan(), 1499.0);
        // Below capacity, merge keeps everything.
        let mut c = LatencyRecorder::bounded(100, 3);
        let mut d = LatencyRecorder::bounded(100, 4);
        c.record(1.0, 0.1);
        d.record(2.0, 0.2);
        c.merge(&d);
        assert_eq!(c.stored(), 2);
        assert_eq!(c.count(), 2);
    }

    #[test]
    fn busy_tracker_utilization() {
        let mut t = BusyTracker::default();
        t.add_busy(0.0, 5.0);
        t.add_busy(6.0, 2.0);
        assert!((t.utilization(10.0) - 0.7).abs() < 1e-12);
        assert_eq!(t.utilization(0.0), 0.0);
    }
}
