//! Serving metrics: latency recording, percentile reports, and windowed
//! throughput — shared by the simulator, the real serving coordinator, and
//! every benchmark harness.

use crate::util::stats::{paper_percentile_grid, percentile};
use std::sync::{Arc, Mutex};

/// Collects per-request latencies and completion times.
#[derive(Debug, Default)]
pub struct LatencyRecorder {
    /// (completion_time_s, latency_s) pairs.
    samples: Vec<(f64, f64)>,
    /// Lazily-built ascending latency view shared by every percentile
    /// query. Percentile callers used to re-sort the full sample vector on
    /// *every* call (the per-epoch reporting loop made that quadratic);
    /// now the first query after a mutation sorts once and the rest reuse
    /// the cached view. Mutations (`record`/`merge`) invalidate it.
    sorted: Mutex<Option<Arc<Vec<f64>>>>,
}

impl Clone for LatencyRecorder {
    fn clone(&self) -> Self {
        Self {
            samples: self.samples.clone(),
            sorted: Mutex::new(self.sorted.lock().unwrap().clone()),
        }
    }
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, completion_s: f64, latency_s: f64) {
        self.samples.push((completion_s, latency_s));
        *self.sorted.get_mut().unwrap() = None;
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn latencies(&self) -> Vec<f64> {
        self.samples.iter().map(|&(_, l)| l).collect()
    }

    /// Time of the last completion (the makespan when arrivals are batched).
    pub fn makespan(&self) -> f64 {
        self.samples.iter().map(|&(t, _)| t).fold(0.0, f64::max)
    }

    /// Overall throughput: completions / makespan.
    pub fn throughput_rps(&self) -> f64 {
        let span = self.makespan();
        if span > 0.0 {
            self.count() as f64 / span
        } else {
            0.0
        }
    }

    /// The sorted latency view behind every percentile query: built on the
    /// first call after a mutation, shared (via `Arc`) afterwards.
    fn sorted_latencies(&self) -> Arc<Vec<f64>> {
        let mut guard = self.sorted.lock().unwrap();
        if let Some(v) = guard.as_ref() {
            return Arc::clone(v);
        }
        let mut v = self.latencies();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let v = Arc::new(v);
        *guard = Some(Arc::clone(&v));
        v
    }

    /// Latency percentile (p in [0,100]).
    pub fn latency_percentile(&self, p: f64) -> f64 {
        percentile(self.sorted_latencies().as_slice(), p)
    }

    /// The paper's p5..p100 latency grid.
    pub fn percentile_grid(&self) -> Vec<(f64, f64)> {
        let v = self.sorted_latencies();
        paper_percentile_grid()
            .into_iter()
            .map(|p| (p, percentile(v.as_slice(), p)))
            .collect()
    }

    pub fn merge(&mut self, other: &LatencyRecorder) {
        self.samples.extend_from_slice(&other.samples);
        *self.sorted.get_mut().unwrap() = None;
    }

    /// Fraction of recorded requests whose latency is within `slo_s` (SLO
    /// attainment). 1.0 for an empty recorder — no request missed the SLO.
    pub fn slo_attainment(&self, slo_s: f64) -> f64 {
        if self.samples.is_empty() {
            return 1.0;
        }
        let within = self
            .samples
            .iter()
            .filter(|&&(_, l)| l <= slo_s)
            .count();
        within as f64 / self.samples.len() as f64
    }
}

/// Tracks busy time for utilization reporting.
#[derive(Clone, Debug, Default)]
pub struct BusyTracker {
    pub busy_s: f64,
    pub last_event_s: f64,
}

impl BusyTracker {
    pub fn add_busy(&mut self, start_s: f64, duration_s: f64) {
        self.busy_s += duration_s;
        self.last_event_s = self.last_event_s.max(start_s + duration_s);
    }

    pub fn utilization(&self, horizon_s: f64) -> f64 {
        if horizon_s > 0.0 {
            (self.busy_s / horizon_s).min(1.0)
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recorder_basics() {
        let mut r = LatencyRecorder::new();
        for i in 1..=10 {
            r.record(i as f64, i as f64 * 0.1);
        }
        assert_eq!(r.count(), 10);
        assert_eq!(r.makespan(), 10.0);
        assert!((r.throughput_rps() - 1.0).abs() < 1e-12);
        assert!((r.latency_percentile(100.0) - 1.0).abs() < 1e-12);
        let grid = r.percentile_grid();
        assert_eq!(grid.len(), 20);
        assert_eq!(grid[19].0, 100.0);
    }

    #[test]
    fn slo_attainment_counts_within_threshold() {
        let mut r = LatencyRecorder::new();
        for i in 1..=10 {
            r.record(i as f64, i as f64); // latencies 1..=10
        }
        assert!((r.slo_attainment(5.0) - 0.5).abs() < 1e-12);
        assert_eq!(r.slo_attainment(0.5), 0.0);
        assert_eq!(r.slo_attainment(100.0), 1.0);
        assert_eq!(LatencyRecorder::new().slo_attainment(1.0), 1.0);
    }

    #[test]
    fn merge_combines() {
        let mut a = LatencyRecorder::new();
        a.record(1.0, 0.5);
        let mut b = LatencyRecorder::new();
        b.record(2.0, 0.7);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.makespan(), 2.0);
    }

    #[test]
    fn cached_percentiles_match_naive_resort() {
        // The cached sorted view must be observationally identical to the
        // old sort-on-every-call behaviour, including across mutations
        // that invalidate it.
        let naive = |r: &LatencyRecorder, p: f64| {
            let mut v = r.latencies();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            percentile(&v, p)
        };
        let mut r = LatencyRecorder::new();
        // Deliberately unsorted arrivals, with duplicates.
        for (i, &l) in [5.0, 1.0, 3.0, 3.0, 9.0, 2.0, 7.0].iter().enumerate() {
            r.record(i as f64, l);
        }
        for p in [0.0, 5.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(r.latency_percentile(p), naive(&r, p), "p{p}");
            // Second call answers from the cache — still identical.
            assert_eq!(r.latency_percentile(p), naive(&r, p), "cached p{p}");
        }
        // Mutating after a cached query must invalidate the view.
        r.record(100.0, 0.5);
        assert_eq!(r.latency_percentile(0.0), 0.5);
        let mut other = LatencyRecorder::new();
        other.record(101.0, 42.0);
        r.merge(&other);
        assert_eq!(r.latency_percentile(100.0), 42.0);
        // A clone carries consistent state too.
        let c = r.clone();
        assert_eq!(c.latency_percentile(50.0), naive(&c, 50.0));
        for (p, v) in r.percentile_grid() {
            assert_eq!(v, naive(&r, p), "grid p{p}");
        }
    }

    #[test]
    fn busy_tracker_utilization() {
        let mut t = BusyTracker::default();
        t.add_busy(0.0, 5.0);
        t.add_busy(6.0, 2.0);
        assert!((t.utilization(10.0) - 0.7).abs() < 1e-12);
        assert_eq!(t.utilization(0.0), 0.0);
    }
}
