"""L1 correctness: the Pallas attention kernel against the pure-jnp oracle.

This is the CORE correctness signal for the compute layer: hypothesis sweeps
shapes (batch, heads, group sizes, sequence/context lengths) and asserts
allclose against ref.attention_ref.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention
from compile.kernels.ref import attention_ref


def run_both(b, hq, hkv, s, t, seed, block_q=16, block_k=64):
    rng = np.random.default_rng(seed)
    d = 32
    q = jnp.asarray(rng.standard_normal((b, hq, s, d), dtype=np.float32))
    k = jnp.asarray(rng.standard_normal((b, hkv, t, d), dtype=np.float32))
    v = jnp.asarray(rng.standard_normal((b, hkv, t, d), dtype=np.float32))
    # lengths in [s, t]: at least the queries themselves are valid.
    lengths = jnp.asarray(rng.integers(s, t + 1, size=(b,)), dtype=jnp.int32)
    out_kernel = attention(q, k, v, lengths, block_q=block_q, block_k=block_k)
    out_ref = attention_ref(q, k, v, lengths)
    return np.asarray(out_kernel), np.asarray(out_ref)


def test_kernel_matches_ref_basic():
    got, want = run_both(b=2, hq=8, hkv=4, s=16, t=64, seed=0)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_kernel_matches_ref_decode_shape():
    # Decode: single query against a long cache.
    got, want = run_both(b=8, hq=8, hkv=4, s=1, t=256, seed=1, block_q=1)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_kernel_matches_ref_mha_no_grouping():
    got, want = run_both(b=1, hq=4, hkv=4, s=32, t=32, seed=2)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_kernel_blocking_invariance():
    # Different block sizes must give identical results.
    a1, _ = run_both(b=2, hq=4, hkv=2, s=32, t=128, seed=3, block_q=8, block_k=32)
    a2, _ = run_both(b=2, hq=4, hkv=2, s=32, t=128, seed=3, block_q=32, block_k=128)
    np.testing.assert_allclose(a1, a2, rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    group=st.integers(1, 4),
    hkv=st.integers(1, 4),
    s_pow=st.integers(0, 5),  # S in {1,2,4,8,16,32}
    t_mult=st.integers(1, 4),  # T = 64 * mult
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(b, group, hkv, s_pow, t_mult, seed):
    s = 2**s_pow
    t = 64 * t_mult
    hq = hkv * group
    block_q = min(16, s)
    got, want = run_both(b, hq, hkv, s, t, seed, block_q=block_q)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_causality_within_queries():
    # A query must not see keys beyond its own position: flip future keys
    # and check outputs of earlier queries don't change.
    rng = np.random.default_rng(7)
    b, hq, hkv, s, t, d = 1, 4, 2, 16, 64, 32
    q = jnp.asarray(rng.standard_normal((b, hq, s, d), dtype=np.float32))
    k = np.asarray(rng.standard_normal((b, hkv, t, d), dtype=np.float32))
    v = np.asarray(rng.standard_normal((b, hkv, t, d), dtype=np.float32))
    lengths = jnp.asarray([s], dtype=jnp.int32)  # queries are positions 0..15
    out1 = np.asarray(attention(q, jnp.asarray(k), jnp.asarray(v), lengths))
    # Corrupt keys at positions >= 8.
    k2, v2 = k.copy(), v.copy()
    k2[:, :, 8:, :] = 99.0
    v2[:, :, 8:, :] = -99.0
    out2 = np.asarray(attention(q, jnp.asarray(k2), jnp.asarray(v2), lengths))
    # Queries 0..7 (positions 0..7) unchanged; query 15 changed.
    np.testing.assert_allclose(out1[:, :, :8], out2[:, :, :8], rtol=1e-6, atol=1e-6)
    assert not np.allclose(out1[:, :, 15], out2[:, :, 15])


def test_length_masking():
    # Keys beyond `lengths` must be invisible.
    rng = np.random.default_rng(9)
    b, hq, hkv, s, t, d = 2, 4, 2, 1, 128, 32
    q = jnp.asarray(rng.standard_normal((b, hq, s, d), dtype=np.float32))
    k = np.asarray(rng.standard_normal((b, hkv, t, d), dtype=np.float32))
    v = np.asarray(rng.standard_normal((b, hkv, t, d), dtype=np.float32))
    lengths = jnp.asarray([40, 100], dtype=jnp.int32)
    out1 = np.asarray(attention(q, jnp.asarray(k), jnp.asarray(v), lengths, block_q=1))
    k2, v2 = k.copy(), v.copy()
    k2[0, :, 40:, :] = 1e3  # beyond length of row 0 only
    v2[0, :, 40:, :] = -1e3
    out2 = np.asarray(attention(q, jnp.asarray(k2), jnp.asarray(v2), lengths, block_q=1))
    np.testing.assert_allclose(out1[0], out2[0], rtol=1e-6, atol=1e-6)


def test_softmax_normalisation():
    # With v = all-ones, attention output must be exactly 1 everywhere
    # (probabilities sum to 1) regardless of q/k.
    rng = np.random.default_rng(11)
    b, hq, hkv, s, t, d = 2, 4, 2, 16, 64, 32
    q = jnp.asarray(rng.standard_normal((b, hq, s, d), dtype=np.float32))
    k = jnp.asarray(rng.standard_normal((b, hkv, t, d), dtype=np.float32))
    v = jnp.ones((b, hkv, t, d), dtype=jnp.float32)
    lengths = jnp.asarray([t, s], dtype=jnp.int32)
    out = np.asarray(attention(q, k, v, lengths))
    np.testing.assert_allclose(out, np.ones_like(out), rtol=1e-5, atol=1e-5)


def test_extreme_logits_stability():
    # Large-magnitude q/k must not produce NaN/Inf (online softmax in f32).
    b, hq, hkv, s, t, d = 1, 2, 1, 8, 64, 32
    q = jnp.full((b, hq, s, d), 30.0, dtype=jnp.float32)
    k = jnp.full((b, hkv, t, d), 30.0, dtype=jnp.float32)
    v = jnp.ones((b, hkv, t, d), dtype=jnp.float32)
    lengths = jnp.asarray([t], dtype=jnp.int32)
    out = np.asarray(attention(q, k, v, lengths, block_q=8))
    assert np.isfinite(out).all()
