"""AOT export contract tests: manifest structure, weight blob layout, and
determinism — the interface the rust runtime depends on."""

import json
import os
import tempfile

import numpy as np
import pytest

from compile import aot
from compile import model as m
from compile.configs import AotBuckets, DEFAULT_CONFIG as CFG


@pytest.fixture(scope="module")
def export_dir():
    """One small export (single prefill + decode bucket) shared by tests."""
    d = tempfile.mkdtemp(prefix="hetserve_aot_test_")
    buckets = AotBuckets(prefill_seq=(16,), decode_batch=(1,), max_seq=256)
    manifest = aot.export(d, seed=0, use_kernel=True, buckets=buckets)
    yield d, manifest
    for f in os.listdir(d):
        os.unlink(os.path.join(d, f))
    os.rmdir(d)


def test_manifest_written_and_consistent(export_dir):
    d, manifest = export_dir
    with open(os.path.join(d, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk["model"]["vocab"] == CFG.vocab
    assert on_disk["model"]["param_count"] == CFG.param_count()
    assert on_disk == json.loads(json.dumps(manifest))
    assert len(on_disk["prefill"]) == 1
    assert on_disk["prefill"][0]["seq"] == 16
    assert len(on_disk["decode"]) == 1


def test_hlo_files_exist_and_look_like_hlo(export_dir):
    d, manifest = export_dir
    for entry in manifest["prefill"] + manifest["decode"]:
        path = os.path.join(d, entry["file"])
        text = open(path).read()
        assert "HloModule" in text, f"{path} is not HLO text"
        assert len(text) > 1000


def test_weights_blob_matches_params(export_dir):
    d, manifest = export_dir
    blob = np.fromfile(os.path.join(d, "weights.bin"), dtype="<f4")
    assert blob.size == manifest["weights_f32_count"]
    params = m.init_params(CFG, seed=0)
    total = sum(int(np.prod(p.shape)) for p in params)
    assert blob.size == total
    # Spot-check: first parameter (embedding) bytes match exactly.
    emb = np.asarray(params[0], dtype="<f4").ravel()
    np.testing.assert_array_equal(blob[: emb.size], emb)
    # Offsets are contiguous and ordered.
    offsets = [p["offset"] for p in manifest["params"]]
    assert offsets == sorted(offsets)
    assert offsets[0] == 0


def test_param_table_matches_model_order(export_dir):
    _, manifest = export_dir
    names = [p["name"] for p in manifest["params"]]
    expected = [n for n, _ in m.param_order(CFG)]
    assert names == expected


def test_export_deterministic():
    buckets = AotBuckets(prefill_seq=(16,), decode_batch=(1,), max_seq=256)
    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        aot.export(d1, seed=0, buckets=buckets)
        aot.export(d2, seed=0, buckets=buckets)
        b1 = open(os.path.join(d1, "weights.bin"), "rb").read()
        b2 = open(os.path.join(d2, "weights.bin"), "rb").read()
        assert b1 == b2
        h1 = open(os.path.join(d1, "prefill_s16.hlo.txt")).read()
        h2 = open(os.path.join(d2, "prefill_s16.hlo.txt")).read()
        assert h1 == h2
