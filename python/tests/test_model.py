"""L2 correctness: model shapes, cache semantics, and kernel-vs-reference
equivalence at the full-model level (prefill + decode chains)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.configs import DEFAULT_CONFIG as CFG
from compile import model as m


@pytest.fixture(scope="module")
def params():
    return m.init_params(CFG, seed=0)


def test_param_count_matches_config(params):
    total = sum(int(np.prod(p.shape)) for p in params)
    assert total == CFG.param_count()


def test_param_order_deterministic(params):
    p2 = m.init_params(CFG, seed=0)
    for a, b in zip(params, p2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    p3 = m.init_params(CFG, seed=1)
    assert any(
        not np.array_equal(np.asarray(a), np.asarray(b)) for a, b in zip(params, p3)
    )


def test_prefill_shapes(params):
    s = 32
    tokens = jnp.arange(s, dtype=jnp.int32).reshape(1, s) % CFG.vocab
    cache = m.empty_cache(CFG, 1)
    logits, new_cache = m.prefill(CFG, params, tokens, cache)
    assert logits.shape == (1, CFG.vocab)
    assert new_cache.shape == cache.shape
    # Cache filled at [0, s), zero beyond.
    filled = np.asarray(new_cache[:, :, :, :s])
    beyond = np.asarray(new_cache[:, :, :, s:])
    assert np.abs(filled).sum() > 0
    np.testing.assert_array_equal(beyond, np.zeros_like(beyond))


def test_decode_step_shapes(params):
    b = 4
    cache = m.empty_cache(CFG, b)
    tokens = jnp.asarray([1, 2, 3, 4], dtype=jnp.int32)
    positions = jnp.asarray([0, 5, 10, 100], dtype=jnp.int32)
    logits, new_cache = m.decode_step(CFG, params, tokens, cache, positions)
    assert logits.shape == (b, CFG.vocab)
    # Each slot wrote exactly at its position (layer 0, key plane).
    delta = np.asarray(new_cache[0, 0]) - np.asarray(cache[0, 0])
    for i, p in enumerate([0, 5, 10, 100]):
        row = np.abs(delta[i]).sum(axis=(1, 2))
        assert row[p] > 0
        assert row.sum() == pytest.approx(row[p], rel=1e-6)


def test_kernel_and_ref_agree_full_model(params):
    s = 16
    tokens = (jnp.arange(s, dtype=jnp.int32) * 7 % CFG.vocab).reshape(1, s)
    cache = m.empty_cache(CFG, 1)
    lk, ck = m.prefill(CFG, params, tokens, cache, use_kernel=True)
    lr, cr = m.prefill(CFG, params, tokens, cache, use_kernel=False)
    np.testing.assert_allclose(np.asarray(lk), np.asarray(lr), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(ck), np.asarray(cr), rtol=2e-4, atol=2e-4)


def test_prefill_then_decode_equals_long_prefill(params):
    """Prefill(S) + decode(token S) must equal Prefill(S+1) logits."""
    s = 16
    rng = np.random.default_rng(3)
    toks = rng.integers(0, CFG.vocab, size=s + 1).astype(np.int32)
    # Path A: prefill all S+1 tokens (needs a bucket-less direct call).
    cache = m.empty_cache(CFG, 1)
    logits_a, _ = m.prefill(CFG, params, jnp.asarray(toks).reshape(1, -1), cache)
    # Path B: prefill S, then decode token S at position S.
    cache = m.empty_cache(CFG, 1)
    _, cache_b = m.prefill(CFG, params, jnp.asarray(toks[:s]).reshape(1, s), cache)
    logits_b, _ = m.decode_step(
        CFG,
        params,
        jnp.asarray(toks[s:]),
        cache_b,
        jnp.asarray([s], dtype=jnp.int32),
    )
    np.testing.assert_allclose(
        np.asarray(logits_a), np.asarray(logits_b), rtol=2e-4, atol=2e-4
    )


def test_greedy_generation_deterministic(params):
    prompt = jnp.asarray([5, 17, 200, 9], dtype=jnp.int32)
    a = m.greedy_generate(CFG, params, prompt, steps=8)
    b = m.greedy_generate(CFG, params, prompt, steps=8)
    assert a == b
    assert len(a) == 8
    assert all(0 <= t < CFG.vocab for t in a)


def test_batch_slots_independent(params):
    """A slot's logits must not depend on other slots' cache contents."""
    b = 2
    tokens = jnp.asarray([42, 42], dtype=jnp.int32)
    positions = jnp.asarray([3, 3], dtype=jnp.int32)
    cache1 = m.empty_cache(CFG, b)
    # Fill slot 1's cache with garbage; slot 0 logits must be unchanged.
    cache2 = cache1.at[:, :, 1].set(7.7)
    l1, _ = m.decode_step(CFG, params, tokens, cache1, positions)
    l2, _ = m.decode_step(CFG, params, tokens, cache2, positions)
    np.testing.assert_allclose(
        np.asarray(l1[0]), np.asarray(l2[0]), rtol=1e-5, atol=1e-5
    )
    assert not np.allclose(np.asarray(l1[1]), np.asarray(l2[1]))
