"""L2 correctness for the factorized revised-simplex prototype: a quick
pytest wrapper around the solver_harness validation suites (cold solves,
warm bound-walks, crash warm starts, long warm chains), each checked
against scipy linprog. The full-size runs live in
``solver_harness/validate.py``; this is the fast CI-sized subset.
"""

import sys
from pathlib import Path

import pytest

pytest.importorskip("scipy")

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "solver_harness"))

import validate  # noqa: E402


def test_cold_solves_match_scipy():
    assert validate.suite_cold(40, 1) == 0


def test_warm_bound_walks_match_scipy():
    bad, dual_used = validate.suite_walk(12, 25, 1)
    assert bad == 0
    # The walk must actually exercise the dual warm path, not fall back to
    # cold solves throughout.
    assert dual_used > 0


def test_crash_warm_starts_match_scipy():
    bad, applied = validate.suite_crash(20, 1)
    assert bad == 0
    assert applied > 0


def test_long_warm_chain_stays_accurate():
    bad, warm, max_dev, max_res = validate.suite_chain(2, 60, 1)
    assert bad == 0
    assert warm > 0
    assert max_dev <= validate.OBJ_TOL
    assert max_res <= 1e-6
