"""Validate the factorized revised-simplex prototype against scipy linprog.

Four suites, mirroring how the Rust arena is used by the planner:

  cold        randomized planner-shaped LPs, cold solve vs scipy (verdict +
              objective)
  walk        warm bound-walk sequences: tighten/widen/fix random variables,
              resolve by dual simplex when the arena says dual_ready, cold
              otherwise; every step checked against scipy at the same bounds
  crash       snapshot -> +-10% coefficient drift -> solve_warm_from on the
              drifted twin, vs scipy
  chain       one arena, hundreds of consecutive warm re-solves on a
              branching-style bound walk with periodic reverts; every step
              vs a fresh cold arena AND scipy (the long-warm-chain numerical
              regression suite)

Run:  python3 validate.py [--quick]
"""

import math
import sys

import numpy as np
from scipy.optimize import linprog

from factor_simplex import (
    EQ,
    GE,
    INF,
    INFEASIBLE,
    LE,
    OPTIMAL,
    UNBOUNDED,
    FactorSimplex,
)

OBJ_TOL = 1e-5


def planner_shaped(rng):
    """Random LP shaped like the planner feasibility model: assignment Eq
    rows, coverage Ge rows, capacity Le rows, integer-ish bounded vars."""
    cand = rng.integers(4, 6)
    wl = rng.integers(3, 5)
    n = cand * wl + cand  # x[w,c] fractions + y[c] replica counts
    c = np.zeros(n)
    lo = np.zeros(n)
    hi = np.zeros(n)
    for k in range(cand * wl):
        hi[k] = 1.0
    for j in range(cand):
        c[cand * wl + j] = rng.uniform(0.5, 4.0)  # replica price
        hi[cand * wl + j] = float(rng.integers(2, 7))
    rows = []
    # assignment: each workload fully routed
    for w in range(wl):
        rows.append(([(w * cand + j, 1.0) for j in range(cand)], EQ, 1.0))
    # throughput coverage: sum_j rate[j,w] * x[w,j] * y[j] is linearized as
    # rate * x only (planner fixes y in the rounding LP); keep it linear.
    for w in range(wl):
        terms = [(w * cand + j, rng.uniform(0.5, 3.0)) for j in range(cand)]
        rows.append((terms, GE, rng.uniform(0.2, 0.9)))
    # capacity: replica counts consume a pooled budget
    rows.append(
        ([(cand * wl + j, rng.uniform(0.5, 2.0)) for j in range(cand)], LE, rng.uniform(4.0, 12.0))
    )
    # makespan-ish coupling rows with mixed signs
    for _ in range(rng.integers(1, 3)):
        terms = []
        for j in range(cand):
            terms.append((w_pick(rng, wl) * cand + j, rng.uniform(-1.0, 2.0)))
            terms.append((cand * wl + j, rng.uniform(-0.5, 1.5)))
        rows.append((terms, LE if rng.random() < 0.7 else GE, rng.uniform(-1.0, 5.0)))
    # sometimes a negative objective entry (exercises phase 1 / primal)
    if rng.random() < 0.3:
        c[rng.integers(0, cand * wl)] = -rng.uniform(0.1, 1.0)
    # sometimes an unbounded-looking column
    if rng.random() < 0.1:
        j = cand * wl + rng.integers(0, cand)
        hi[j] = INF
    return n, c, rows, lo, hi


def w_pick(rng, wl):
    return int(rng.integers(0, wl))


def scipy_solve(n, c, rows, lo, hi):
    a_ub, b_ub, a_eq, b_eq = [], [], [], []
    for terms, cmp, rhs in rows:
        row = np.zeros(n)
        for j, a in terms:
            row[j] += a
        if cmp == LE:
            a_ub.append(row)
            b_ub.append(rhs)
        elif cmp == GE:
            a_ub.append(-row)
            b_ub.append(-rhs)
        else:
            a_eq.append(row)
            b_eq.append(rhs)
    bounds = [(lo[j], None if hi[j] == INF else hi[j]) for j in range(n)]
    res = linprog(
        c,
        A_ub=np.array(a_ub) if a_ub else None,
        b_ub=np.array(b_ub) if b_ub else None,
        A_eq=np.array(a_eq) if a_eq else None,
        b_eq=np.array(b_eq) if b_eq else None,
        bounds=bounds,
        method="highs",
    )
    if res.status == 0:
        return OPTIMAL, res.fun
    if res.status == 2:
        return INFEASIBLE, None
    if res.status == 3:
        return UNBOUNDED, None
    return "other", None


def check_against_scipy(fs, out, n, c, rows, lo, hi, label):
    want, obj = scipy_solve(n, c, rows, lo, hi)
    if want == "other":
        return True  # scipy numerical trouble; skip
    if out != want:
        print(f"MISMATCH[{label}] verdict ours={out} scipy={want}")
        return False
    if out == OPTIMAL:
        _, ours = fs.extract()
        if abs(ours - obj) > OBJ_TOL * (1.0 + abs(obj)):
            print(f"MISMATCH[{label}] objective ours={ours:.9f} scipy={obj:.9f}")
            return False
        if fs.residual() > 1e-6:
            print(f"MISMATCH[{label}] residual {fs.residual():.2e}")
            return False
    return True


def suite_cold(ncases, seed0):
    bad = 0
    for k in range(ncases):
        rng = np.random.default_rng(seed0 + k)
        n, c, rows, lo, hi = planner_shaped(rng)
        fs = FactorSimplex(n, c, rows, lo, hi)
        out = fs.solve_cold()
        if not check_against_scipy(fs, out, n, c, rows, lo, hi, f"cold#{k}"):
            bad += 1
    return bad


def suite_walk(ncases, steps, seed0):
    bad = 0
    dual_used = 0
    for k in range(ncases):
        rng = np.random.default_rng(10_000 + seed0 + k)
        n, c, rows, lo, hi = planner_shaped(rng)
        fs = FactorSimplex(n, c, rows, lo, hi)
        out = fs.solve_cold()
        cur = [(lo[j], hi[j]) for j in range(n)]
        for s in range(steps):
            v = int(rng.integers(0, n))
            olo, ohi = cur[v]
            mode = rng.random()
            if mode < 0.35 and ohi != INF:  # fix (branching down/up)
                t = round(rng.uniform(olo, ohi if ohi != INF else olo + 3))
                nlo = nhi = float(t)
            elif mode < 0.6:  # tighten upper
                nlo, nhi = olo, (olo + ohi) / 2 if ohi != INF else olo + 1.0
            elif mode < 0.8:  # tighten lower
                nlo = math.ceil((olo + (ohi if ohi != INF else olo + 2)) / 2)
                nhi = ohi
                if nlo > (nhi if nhi != INF else nlo):
                    nlo = olo
            else:  # revert / widen
                nlo, nhi = 0.0, ohi if ohi != INF else INF
                if v < n and rng.random() < 0.3:
                    nhi = INF
            fs.set_var_bounds(v, nlo, nhi)
            cur[v] = (nlo, nhi)
            if fs.dual_ready():
                out = fs.resolve_dual()
                dual_used += 1
            else:
                out = fs.solve_cold()
            lo2 = np.array([a for a, _ in cur])
            hi2 = np.array([b for _, b in cur])
            if out == "stalled":
                out = fs.solve_cold()
            if not check_against_scipy(fs, out, n, c, rows, lo2, hi2, f"walk#{k}.{s}"):
                bad += 1
                break
    return bad, dual_used


def suite_crash(ncases, seed0):
    bad = 0
    applied = 0
    for k in range(ncases):
        rng = np.random.default_rng(20_000 + seed0 + k)
        n, c, rows, lo, hi = planner_shaped(rng)
        fs = FactorSimplex(n, c, rows, lo, hi)
        if fs.solve_cold() != OPTIMAL:
            continue
        snap = fs.snapshot()
        # +-10% coefficient drift, same structure
        rows2 = []
        for terms, cmp, rhs in rows:
            rows2.append(
                (
                    [(j, a * rng.uniform(0.9, 1.1)) for j, a in terms],
                    cmp,
                    rhs * rng.uniform(0.9, 1.1),
                )
            )
        c2 = c * rng.uniform(0.9, 1.1, size=n)
        fs2 = FactorSimplex(n, c2, rows2, lo, hi)
        out = fs2.solve_warm_from(snap)
        if out is None:
            continue
        applied += 1
        if not check_against_scipy(fs2, out, n, c2, rows2, lo, hi, f"crash#{k}"):
            bad += 1
    return bad, applied


def suite_chain(nchains, length, seed0):
    """Long warm chains: one arena re-solved warm for `length` consecutive
    branching steps; objective vs a fresh cold arena at every step."""
    bad = 0
    warm = 0
    max_dev = 0.0
    max_res = 0.0
    for k in range(nchains):
        rng = np.random.default_rng(30_000 + seed0 + k)
        n, c, rows, lo, hi = planner_shaped(rng)
        fs = FactorSimplex(n, c, rows, lo, hi)
        fs.solve_cold()
        ints = [j for j in range(n) if hi[j] != INF]
        cur = [(lo[j], hi[j]) for j in range(n)]
        base = [(lo[j], hi[j]) for j in range(n)]
        for s in range(length):
            if rng.random() < 0.25:  # backtrack: revert one var to root bounds
                v = int(rng.integers(0, n))
                nlo, nhi = base[v]
            else:  # branch: fix or halve an integer-ish var
                v = ints[int(rng.integers(0, len(ints)))]
                olo, ohi = cur[v]
                if olo == ohi or rng.random() < 0.5:
                    t = float(rng.integers(0, int(base[v][1]) + 1))
                    nlo = nhi = t
                else:
                    nlo, nhi = olo, max(olo, math.floor((olo + ohi) / 2))
            fs.set_var_bounds(v, nlo, nhi)
            cur[v] = (nlo, nhi)
            if fs.dual_ready():
                out = fs.resolve_dual()
                warm += 1
            else:
                out = fs.solve_cold()
            if out == "stalled":
                out = fs.solve_cold()
            # cold reference arena at identical bounds
            lo2 = np.array([a for a, _ in cur])
            hi2 = np.array([b for _, b in cur])
            ref = FactorSimplex(n, c, rows, lo2, hi2)
            rout = ref.solve_cold()
            if out != rout:
                print(f"CHAIN[{k}.{s}] verdict warm={out} cold={rout}")
                bad += 1
                break
            if out == OPTIMAL:
                _, wobj = fs.extract()
                _, cobj = ref.extract()
                dev = abs(wobj - cobj) / (1.0 + abs(cobj))
                max_dev = max(max_dev, dev)
                max_res = max(max_res, fs.residual())
                if dev > OBJ_TOL:
                    print(f"CHAIN[{k}.{s}] obj warm={wobj:.9f} cold={cobj:.9f}")
                    bad += 1
                    break
                if not check_against_scipy(fs, out, n, c, rows, lo2, hi2, f"chain#{k}.{s}"):
                    bad += 1
                    break
    return bad, warm, max_dev, max_res


def main():
    quick = "--quick" in sys.argv
    ncold = 60 if quick else 300
    nwalk = 20 if quick else 80
    ncrash = 30 if quick else 150
    nchain = 2 if quick else 6
    chain_len = 60 if quick else 250

    bad = suite_cold(ncold, 1)
    print(f"cold : {ncold} LPs, {bad} mismatches")
    total_bad = bad

    bad, dual_used = suite_walk(nwalk, 25, 1)
    print(f"walk : {nwalk} walks x 25 steps, {bad} mismatches, {dual_used} dual re-solves")
    total_bad += bad

    bad, applied = suite_crash(ncrash, 1)
    print(f"crash: {ncrash} drifted twins, {applied} applied, {bad} mismatches")
    total_bad += bad

    bad, warm, max_dev, max_res = suite_chain(nchain, chain_len, 1)
    print(
        f"chain: {nchain} chains x {chain_len}, {bad} mismatches, "
        f"{warm} warm, max obj dev {max_dev:.2e}, max residual {max_res:.2e}"
    )
    total_bad += bad

    if total_bad:
        print(f"FAIL: {total_bad} mismatches")
        sys.exit(1)
    print("OK: factorized revised simplex matches scipy on all suites")


if __name__ == "__main__":
    main()
