"""Reference prototype of the factorized bounded-variable revised simplex.

This is the algorithm-validation twin of ``rust/src/milp/{factor,bounds}.rs``:
an *unshifted* bounded-variable revised simplex over an LU-factorized basis
with a product-form eta file, periodic refactorisation, dual steepest-edge
pricing (Forrest-Goldfarb reference weights) and a composite phase-1 primal.
The Rust implementation is a line-for-line transcription of this file;
``validate.py`` / ``tests/test_factor_simplex.py`` check it against scipy
``linprog`` on randomized planner-shaped LPs, including warm bound-walk and
crash-warm sequences.

Problem form (mirrors ``milp::simplex::Lp``)::

    min c.x   s.t.  A x {<=,>=,=} b,   lo <= x <= hi

One logical column per row (total = n + m): ``a_i.x + s_i = b_i`` with
``s_i in [0, inf)`` for Le, ``(-inf, 0]`` for Ge (resting at upper bound 0)
and ``[0, 0]`` for Eq.  No artificial variables: cold starts are classified
as primal-feasible (primal phase 2), dual-feasible (dual simplex) or neither
(composite phase 1 minimizing the sum of infeasibilities).
"""

import math

import numpy as np

INF = math.inf
DTOL = 1e-7  # dual feasibility tolerance on reduced costs
FTOL = 1e-7  # primal feasibility tolerance on basic values
ATOL = 1e-9  # treat tableau coefficients below this as zero
SING_EPS = 1e-10  # factorization pivot magnitude below this = singular
RATIO_TIE = 1e-7  # near-tie window in ratio tests (prefer big pivots)
GAMMA_FLOOR = 1e-10  # dual steepest-edge weight floor

LE, GE, EQ = 0, 1, 2

OPTIMAL = "optimal"
INFEASIBLE = "infeasible"
UNBOUNDED = "unbounded"
STALLED = "stalled"


def beats(val, best):
    """Ratio-test comparison: (strictly better, within the near-tie window).

    ``best == INF`` counts as strictly beaten by any finite value (the
    subtraction form would produce NaN there).
    """
    if not math.isfinite(best):
        return math.isfinite(val), False
    win = RATIO_TIE * (1.0 + abs(best))
    better = val < best - win
    return better, (not better) and val <= best + win


class FactorSimplex:
    """Bounded-variable revised simplex over an LU+eta factorized basis."""

    def __init__(self, n, c, rows, lo, hi):
        self.n = n
        self.m = m = len(rows)
        total = self.total = n + m
        self.c = np.zeros(total)
        self.c[:n] = c
        self.A = np.zeros((m, total))
        self.b = np.zeros(m)
        self.lo = np.full(total, 0.0)
        self.hi = np.full(total, 0.0)
        self.lo[:n] = lo
        self.hi[:n] = hi
        for i, (terms, cmp, rhs) in enumerate(rows):
            for j, a in terms:
                self.A[i, j] += a
            self.A[i, n + i] = 1.0
            self.b[i] = rhs
            if cmp == LE:
                self.lo[n + i], self.hi[n + i] = 0.0, INF
            elif cmp == GE:
                self.lo[n + i], self.hi[n + i] = -INF, 0.0
            else:
                self.lo[n + i], self.hi[n + i] = 0.0, 0.0
        self.basis = np.array([n + i for i in range(m)], dtype=int)
        self.pos = np.full(total, -1, dtype=int)
        for i, j in enumerate(self.basis):
            self.pos[j] = i
        self.at_upper = np.zeros(total, dtype=bool)
        self.xb = np.zeros(m)
        self.xb_dirty = True
        self.dual_ok = False
        self.y = np.zeros(m)
        self.lu = None
        self.perm = None
        self.etas = []
        self.need_factor = True
        self.gamma = np.ones(m)
        # stats
        self.pivots = 0
        self.bound_flips = 0
        self.refactorisations = 0
        self.eta_updates = 0
        self.dse_pivots = 0

    # ---------------- factorization ----------------

    def eta_limit(self):
        return max(2 * self.m, 20)

    def factorize(self):
        """(Re)factorize B = A[:, basis] as P.B = L.U with partial pivoting.

        A dependent basis column is repaired by substituting the logical of
        an unpivoted row (snapshot crash across coefficient drift can hand
        us a singular basis); the ejected variable rests at a finite bound.
        """
        m = self.m
        for _attempt in range(m + 1):
            lu = self.A[:, self.basis].copy()
            perm = np.arange(m)
            ok = True
            for k in range(m):
                p = k + int(np.argmax(np.abs(lu[k:, k])))
                if abs(lu[p, k]) < SING_EPS:
                    if not self._repair_singular(k, perm):
                        raise RuntimeError("unrepairable singular basis")
                    ok = False
                    break
                if p != k:
                    lu[[k, p], :] = lu[[p, k], :]
                    perm[[k, p]] = perm[[p, k]]
                piv = lu[k, k]
                if k + 1 < m:
                    lu[k + 1 :, k] /= piv
                    lu[k + 1 :, k + 1 :] -= np.outer(lu[k + 1 :, k], lu[k, k + 1 :])
            if ok:
                self.lu = lu
                self.perm = perm
                self.etas = []
                self.gamma = np.ones(m)
                self.refactorisations += 1
                self.need_factor = False
                return
        raise RuntimeError("factorize loop did not converge")

    def _repair_singular(self, k, perm):
        # basis column k is dependent on columns 0..k-1: swap in the logical
        # of a not-yet-pivoted row (one of perm[k:]) that is nonbasic.
        for q in range(k, self.m):
            lg = self.n + int(perm[q])
            if self.pos[lg] < 0:
                old = self.basis[k]
                self.pos[old] = -1
                if math.isfinite(self.lo[old]):
                    self.at_upper[old] = False
                elif math.isfinite(self.hi[old]):
                    self.at_upper[old] = True
                self.basis[k] = lg
                self.pos[lg] = k
                self.xb_dirty = True
                return True
        return False

    def ftran(self, v):
        """Solve B.x = v through the LU factors and the eta file."""
        m = self.m
        x = np.asarray(v, dtype=float)[self.perm].copy()
        for k in range(m):
            if x[k] != 0.0:
                x[k + 1 :] -= self.lu[k + 1 :, k] * x[k]
        for k in range(m - 1, -1, -1):
            if k + 1 < m:
                x[k] -= self.lu[k, k + 1 :] @ x[k + 1 :]
            x[k] /= self.lu[k, k]
        for r, alpha in self.etas:
            t = x[r] / alpha[r]
            if t != 0.0:
                x -= alpha * t
            x[r] = t
        return x

    def btran(self, v):
        """Solve B^T.x = v: reversed eta file first, then the LU transpose."""
        m = self.m
        x = np.asarray(v, dtype=float).copy()
        for r, alpha in reversed(self.etas):
            x[r] = (x[r] - (alpha @ x - alpha[r] * x[r])) / alpha[r]
        for k in range(m):
            if k > 0:
                x[k] -= self.lu[:k, k] @ x[:k]
            x[k] /= self.lu[k, k]
        for k in range(m - 1, -1, -1):
            if k + 1 < m:
                x[k] -= self.lu[k + 1 :, k] @ x[k + 1 :]
        out = np.zeros(m)
        out[self.perm] = x
        return out

    # ---------------- state helpers ----------------

    def nb_val(self, j):
        if self.at_upper[j]:
            if math.isfinite(self.hi[j]):
                return self.hi[j]
            return self.lo[j] if math.isfinite(self.lo[j]) else 0.0
        if math.isfinite(self.lo[j]):
            return self.lo[j]
        return self.hi[j] if math.isfinite(self.hi[j]) else 0.0

    def compute_xb(self):
        rhs = self.b.copy()
        for j in range(self.total):
            if self.pos[j] < 0:
                v = self.nb_val(j)
                if v != 0.0:
                    rhs -= self.A[:, j] * v
        self.xb = self.ftran(rhs)
        self.xb_dirty = False

    def price_full(self, cvec):
        """y = B^-T c_B; returns reduced costs d = c - y.A for all columns."""
        y = self.btran(cvec[self.basis])
        if cvec is self.c:
            self.y = y
        return cvec - y @ self.A, y

    def push_pivot(self, r, q, alpha):
        leaving = self.basis[r]
        self.pos[leaving] = -1
        self.basis[r] = q
        self.pos[q] = r
        self.etas.append((r, alpha.copy()))
        self.eta_updates += 1
        self.pivots += 1
        if len(self.etas) >= self.eta_limit():
            self.factorize()
            self.compute_xb()

    def primal_feasible(self):
        for i in range(self.m):
            j = self.basis[i]
            if self.xb[i] < self.lo[j] - FTOL or self.xb[i] > self.hi[j] + FTOL:
                return False
        return True

    def dual_feasible(self):
        d, _ = self.price_full(self.c)
        for j in range(self.total):
            if self.pos[j] >= 0 or self.lo[j] == self.hi[j]:
                continue
            if self.at_upper[j] and math.isfinite(self.hi[j]):
                if d[j] > DTOL:
                    return False
            elif math.isfinite(self.lo[j]) and not self.at_upper[j]:
                if d[j] < -DTOL:
                    return False
            elif abs(d[j]) > DTOL:  # free column resting at 0
                return False
        return True

    def max_iters(self):
        return 50 * max(self.m + self.total, 100)

    # ---------------- primal phase 2 ----------------

    def primal2(self):
        cap = self.max_iters()
        it = 0
        while True:
            it += 1
            if it > cap:
                return STALLED
            bland = it > cap // 2
            d, _ = self.price_full(self.c)
            q, sigma, score = -1, 0, DTOL
            for j in range(self.total):
                if self.pos[j] >= 0 or self.lo[j] == self.hi[j]:
                    continue
                up = self.at_upper[j] and math.isfinite(self.hi[j])
                if not up and d[j] < -DTOL:
                    s, sg = -d[j], 1
                elif (up or not math.isfinite(self.lo[j])) and d[j] > DTOL:
                    s, sg = d[j], -1
                else:
                    continue
                if bland:
                    q, sigma = j, sg
                    break
                if s > score:
                    q, sigma, score = j, sg, s
            if q < 0:
                return OPTIMAL
            alpha = self.ftran(self.A[:, q])
            out = self._primal_step(q, sigma, alpha, bland)
            if out is not None:
                return out

    def _primal_step(self, q, sigma, alpha, bland):
        """Bounded ratio test + pivot/flip for entering q moving sigma*t."""
        rng = self.hi[q] - self.lo[q]
        t_best = rng if math.isfinite(rng) else INF
        block, leave_up, mag = -1, False, 0.0
        for i in range(self.m):
            a = sigma * alpha[i]
            if abs(a) <= ATOL:
                continue
            j = self.basis[i]
            if a > 0.0:  # basic value decreases toward its lower bound
                if not math.isfinite(self.lo[j]):
                    continue
                t = (self.xb[i] - self.lo[j]) / a
                lu = False
            else:  # increases toward its upper bound
                if not math.isfinite(self.hi[j]):
                    continue
                t = (self.hi[j] - self.xb[i]) / (-a)
                lu = True
            if t < 0.0:
                t = 0.0
            better, tied = beats(t, t_best)
            if better or (tied and not bland and abs(alpha[i]) > mag):
                t_best, block, leave_up, mag = min(t, t_best) if tied else t, i, lu, abs(alpha[i])
        if t_best == INF:
            return UNBOUNDED
        if block < 0:
            # bound flip: entering crosses its whole range, no pivot
            self.xb -= sigma * alpha * t_best
            self.at_upper[q] = not self.at_upper[q]
            self.bound_flips += 1
            return None
        self.xb -= sigma * alpha * t_best
        newval = self.nb_val(q) + sigma * t_best
        self.at_upper[self.basis[block]] = leave_up
        self.xb[block] = newval
        self.push_pivot(block, q, alpha)
        return None

    # ---------------- dual simplex with steepest-edge ----------------

    def dual_loop(self):
        cap = self.max_iters()
        it = 0
        while True:
            it += 1
            if it > cap:
                return STALLED
            bland = it > cap // 2
            r, score = -1, 0.0
            for i in range(self.m):
                j = self.basis[i]
                if self.xb[i] < self.lo[j] - FTOL:
                    delta = self.lo[j] - self.xb[i]
                elif self.xb[i] > self.hi[j] + FTOL:
                    delta = self.xb[i] - self.hi[j]
                else:
                    continue
                s = delta * delta / self.gamma[i]
                if bland:
                    r = i
                    break
                if s > score:
                    r, score = i, s
            if r < 0:
                return OPTIMAL
            j_leave = self.basis[r]
            below = self.xb[r] < self.lo[j_leave]
            rho = self.btran(np.eye(self.m)[r])
            d, _ = self.price_full(self.c)
            row = rho @ self.A
            q, best, mag = -1, INF, 0.0
            for j in range(self.total):
                if self.pos[j] >= 0 or self.lo[j] == self.hi[j]:
                    continue
                arj = row[j]
                if abs(arj) <= ATOL:
                    continue
                up = self.at_upper[j] and math.isfinite(self.hi[j])
                if below:
                    if not up and arj < -ATOL:
                        ratio = max(d[j], 0.0) / (-arj)
                    elif up and arj > ATOL:
                        ratio = max(-d[j], 0.0) / arj
                    else:
                        continue
                else:
                    if not up and arj > ATOL:
                        ratio = max(d[j], 0.0) / arj
                    elif up and arj < -ATOL:
                        ratio = max(-d[j], 0.0) / (-arj)
                    else:
                        continue
                better, tied = beats(ratio, best)
                if better or (tied and not bland and abs(arj) > mag):
                    best, q, mag = min(ratio, best) if tied else ratio, j, abs(arj)
            if q < 0:
                return INFEASIBLE  # dual unbounded => primal infeasible
            alpha = self.ftran(self.A[:, q])
            if abs(alpha[r]) <= ATOL:
                # refactorize and retry once; a pivot this small is drift
                self.factorize()
                self.compute_xb()
                continue
            sigma = 1 if not (self.at_upper[q] and math.isfinite(self.hi[q])) else -1
            target = self.lo[j_leave] if below else self.hi[j_leave]
            t = (target - self.xb[r]) / (-sigma * alpha[r])
            if t < 0.0:
                t = 0.0
            # Forrest-Goldfarb weight update before the basis change
            tau = self.ftran(rho)
            gr = self.gamma[r]
            ar = alpha[r]
            for i in range(self.m):
                if i == r:
                    continue
                w = alpha[i] / ar
                self.gamma[i] = max(self.gamma[i] - 2.0 * w * tau[i] + w * w * gr, GAMMA_FLOOR)
            self.gamma[r] = max(gr / (ar * ar), GAMMA_FLOOR)
            self.xb -= sigma * alpha * t
            newval = self.nb_val(q) + sigma * t
            self.at_upper[j_leave] = not below
            self.xb[r] = newval
            self.push_pivot(r, q, alpha)
            self.dse_pivots += 1

    # ---------------- composite phase 1 ----------------

    def phase1(self):
        cap = self.max_iters()
        it = 0
        while True:
            it += 1
            if it > cap:
                return STALLED
            bland = it > cap // 2
            w = np.zeros(self.total)
            infeas = 0.0
            for i in range(self.m):
                j = self.basis[i]
                if self.xb[i] < self.lo[j] - FTOL:
                    w[j] = -1.0
                    infeas += self.lo[j] - self.xb[i]
                elif self.xb[i] > self.hi[j] + FTOL:
                    w[j] = 1.0
                    infeas += self.xb[i] - self.hi[j]
            if infeas <= FTOL:
                return OPTIMAL
            d, _ = self.price_full(w)
            q, sigma, score = -1, 0, DTOL
            for j in range(self.total):
                if self.pos[j] >= 0 or self.lo[j] == self.hi[j]:
                    continue
                up = self.at_upper[j] and math.isfinite(self.hi[j])
                if not up and d[j] < -DTOL:
                    s, sg = -d[j], 1
                elif (up or not math.isfinite(self.lo[j])) and d[j] > DTOL:
                    s, sg = d[j], -1
                else:
                    continue
                if bland:
                    q, sigma = j, sg
                    break
                if s > score:
                    q, sigma, score = j, sg, s
            if q < 0:
                return INFEASIBLE
            alpha = self.ftran(self.A[:, q])
            out = self._phase1_step(q, sigma, alpha, bland)
            if out is not None:
                return out

    def _phase1_step(self, q, sigma, alpha, bland):
        """Short-step ratio test: stop at the first bound crossing."""
        rng = self.hi[q] - self.lo[q]
        t_best = rng if math.isfinite(rng) else INF
        block, leave_up, mag = -1, False, 0.0
        for i in range(self.m):
            a = sigma * alpha[i]
            if abs(a) <= ATOL:
                continue
            j = self.basis[i]
            v = self.xb[i]
            t, lu = None, False
            if a > 0.0:  # basic decreases
                if v > self.hi[j] + FTOL:
                    t, lu = (v - self.hi[j]) / a, True
                elif v >= self.lo[j] - FTOL and math.isfinite(self.lo[j]):
                    t, lu = (v - self.lo[j]) / a, False
            else:  # basic increases
                if v < self.lo[j] - FTOL:
                    t, lu = (self.lo[j] - v) / (-a), False
                elif v <= self.hi[j] + FTOL and math.isfinite(self.hi[j]):
                    t, lu = (self.hi[j] - v) / (-a), True
            if t is None:
                continue
            if t < 0.0:
                t = 0.0
            better, tied = beats(t, t_best)
            if better or (tied and not bland and abs(alpha[i]) > mag):
                t_best, block, leave_up, mag = min(t, t_best) if tied else t, i, lu, abs(alpha[i])
        if t_best == INF:
            return STALLED
        if block < 0:
            self.xb -= sigma * alpha * t_best
            self.at_upper[q] = not self.at_upper[q]
            self.bound_flips += 1
            return None
        self.xb -= sigma * alpha * t_best
        newval = self.nb_val(q) + sigma * t_best
        self.at_upper[self.basis[block]] = leave_up
        self.xb[block] = newval
        self.push_pivot(block, q, alpha)
        return None

    # ---------------- public API (mirrors BoundedSimplex) ----------------

    def solve_cold(self):
        n, m = self.n, self.m
        self.basis = np.array([n + i for i in range(m)], dtype=int)
        self.pos = np.full(self.total, -1, dtype=int)
        for i, j in enumerate(self.basis):
            self.pos[j] = i
        for j in range(n):
            self.at_upper[j] = self.c[j] < 0.0 and math.isfinite(self.hi[j])
        for i in range(m):
            self.at_upper[n + i] = not math.isfinite(self.lo[n + i])
        self.factorize()
        self.compute_xb()
        return self._finish()

    def _finish(self):
        if self.primal_feasible():
            out = self.primal2()
        elif self.dual_feasible():
            out = self.dual_loop()
            if out == OPTIMAL:
                out = self.primal2()
        else:
            out = self.phase1()
            if out == OPTIMAL:
                out = self.primal2()
        if out == OPTIMAL:
            self.dual_ok = True
            self.price_full(self.c)  # refresh cached y at the terminal basis
        return out

    def resolve_dual(self):
        if self.need_factor:
            self.factorize()
        if self.xb_dirty:
            self.compute_xb()
        out = self.dual_loop()
        if out == OPTIMAL:
            out = self.primal2()
        if out == OPTIMAL:
            self.dual_ok = True
            self.price_full(self.c)
        return out

    def dual_ready(self):
        return self.dual_ok

    def var_bounds(self, v):
        return self.lo[v], self.hi[v]

    def set_var_bounds(self, v, lo, hi):
        self.lo[v], self.hi[v] = lo, hi
        self.xb_dirty = True
        if self.pos[v] >= 0 or lo == hi:
            return  # basic: bounds only re-score feasibility; fixed: any d
        # nonbasic: keep a rest side whose sign condition matches d_v;
        # reduced costs are bound-independent in the unshifted form, so the
        # cached y prices d_v exactly.
        dv = self.c[v] - self.y @ self.A[:, v]
        lower_ok = math.isfinite(lo) and dv >= -DTOL
        upper_ok = math.isfinite(hi) and dv <= DTOL
        if self.at_upper[v]:
            if upper_ok:
                return
            if lower_ok:
                self.at_upper[v] = False
                return
        else:
            if lower_ok:
                return
            if upper_ok:
                self.at_upper[v] = True
                return
        if math.isfinite(lo):
            self.at_upper[v] = False
            self.dual_ok = False
        elif math.isfinite(hi):
            self.at_upper[v] = True
            self.dual_ok = False
        else:
            self.at_upper[v] = False
            if abs(dv) > DTOL:
                self.dual_ok = False

    def snapshot(self):
        return dict(
            n=self.n,
            m=self.m,
            total=self.total,
            basis=self.basis.copy(),
            flipped=self.at_upper.copy(),
        )

    def solve_warm_from(self, snap):
        if snap["n"] != self.n or snap["m"] != self.m or snap["total"] != self.total:
            return None
        self.basis = snap["basis"].copy()
        self.at_upper = snap["flipped"].copy()
        self.pos = np.full(self.total, -1, dtype=int)
        for i, j in enumerate(self.basis):
            self.pos[j] = i
        self.factorize()
        self.compute_xb()
        return self._finish()

    def extract(self):
        x = np.array([self.nb_val(j) for j in range(self.total)])
        for i in range(self.m):
            x[self.basis[i]] = self.xb[i]
        return x[: self.n].copy(), float(self.c @ x)

    def residual(self):
        """Max row violation of A.x = b at the current factorized point."""
        x = np.array([self.nb_val(j) for j in range(self.total)])
        for i in range(self.m):
            x[self.basis[i]] = self.xb[i]
        return float(np.max(np.abs(self.A @ x - self.b))) if self.m else 0.0
