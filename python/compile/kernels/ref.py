"""Pure-jnp oracle implementations.

These are the correctness references for the Pallas kernels: simple, obviously
correct jax.numpy code with no tiling or fusion tricks. pytest compares the
kernels against these under randomized shapes (python/tests/test_kernel.py).
"""

import jax.numpy as jnp


def attention_ref(q, k, v, lengths, scale=None):
    """Masked multi-head attention with grouped KV heads.

    Args:
      q: [B, Hq, S, D] queries.
      k: [B, Hkv, T, D] keys (padded to T; only the first ``lengths[b]``
         positions are valid).
      v: [B, Hkv, T, D] values.
      lengths: [B] int32 — valid KV length per batch element. Queries attend
         causally *within* the valid region: query at position
         (lengths[b] - S + i) sees keys [0, lengths[b] - S + i].
      scale: softmax scale; defaults to 1/sqrt(D).

    Returns:
      [B, Hq, S, D] attention output, f32.
    """
    b, hq, s, d = q.shape
    _, hkv, t, _ = k.shape
    assert hq % hkv == 0, "query heads must be a multiple of kv heads"
    group = hq // hkv
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))

    # Expand kv heads to match query heads.
    k = jnp.repeat(k, group, axis=1)  # [B, Hq, T, D]
    v = jnp.repeat(v, group, axis=1)

    logits = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32) * scale

    # Position mask: key position j is visible to query i (the i-th of the
    # final S positions) iff j <= lengths[b] - S + i.
    key_pos = jnp.arange(t)[None, None, :]  # [1, 1, T]
    q_end = lengths[:, None, None]  # [B, 1, 1]
    q_pos = q_end - s + jnp.arange(s)[None, :, None]  # [B, S, 1]
    mask = key_pos <= q_pos  # [B, S, T]
    logits = jnp.where(mask[:, None, :, :], logits, -jnp.inf)

    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhst,bhtd->bhsd", probs.astype(v.dtype), v)


def rmsnorm_ref(x, weight, eps=1e-5):
    """RMSNorm over the last axis."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jnp.reciprocal(jnp.sqrt(var + eps))).astype(x.dtype) * weight


def swiglu_ref(x, w_gate, w_up, w_down):
    """SwiGLU MLP: down( silu(x @ gate) * (x @ up) )."""
    g = x @ w_gate
    u = x @ w_up
    silu = g * (1.0 / (1.0 + jnp.exp(-g)))
    return (silu * u) @ w_down


def rope_ref(x, positions, theta=10000.0):
    """Rotary position embedding.

    Args:
      x: [..., S, D] with D even.
      positions: [S] int32 absolute positions (broadcast over leading dims).
    """
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [S, D/2]
    cos = jnp.cos(angles)
    sin = jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)
