"""L1: fused attention as a Pallas kernel (flash-attention structure).

TPU adaptation of the usual CUDA flash attention (DESIGN.md
§Hardware-Adaptation): instead of warp tiles and shared memory we tile for
VMEM with `BlockSpec`s — the grid walks (batch, query-head, query-block),
each program holds one query block plus the full (small) KV stream for its
grouped KV head in VMEM, and the KV axis is consumed in blocks with an
online-softmax accumulator in f32. On a real TPU the same structure maps the
HBM→VMEM schedule; here it must run with ``interpret=True`` because the CPU
PJRT plugin cannot execute Mosaic custom-calls.

§Perf note: a head-folded variant (grid over batch only, all heads in one
program) was tried to cut interpret-mode per-program overhead; it measured
~2× *slower* on the AOT CPU path (decode b=1: 143 ms → 311 ms) because the
inlined HLO body grew faster than the program count shrank, so this
head-per-program layout is the kept configuration. See EXPERIMENTS.md §Perf.

VMEM budget at the default tiny-model shapes (T=256, D=32, f32):
q block 16×32 (2 KB) + K,V 256×32×2 (64 KB) + accumulators ≈ 70 KB per
program — comfortably under the ~16 MB VMEM of a TPU core, leaving room for
the compiler to double-buffer the KV stream.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Negative "infinity" that survives exp() without NaNs.
_NEG_INF = -1e30


def _attention_kernel(s_total, block_k, scale, len_ref, q_ref, k_ref, v_ref, o_ref):
    """One (batch, q-head, q-block) program.

    Shapes (leading singleton dims are the blocked batch/head axes):
      len_ref: [1]            valid KV length for this batch element
      q_ref:   [1, 1, BQ, D]
      k_ref:   [1, 1, T, D]   full KV stream for the grouped head
      v_ref:   [1, 1, T, D]
      o_ref:   [1, 1, BQ, D]
    """
    qb = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)  # [BQ, D]
    block_q, d = q.shape
    t = k_ref.shape[2]
    length = len_ref[0]

    # Absolute positions of this block's queries: the S queries are the
    # *last* S positions of the sequence, so query i sits at
    # length - s_total + qb*BQ + i.
    q_pos = length - s_total + qb * block_q + jax.lax.iota(jnp.int32, block_q)

    m = jnp.full((block_q,), _NEG_INF, dtype=jnp.float32)
    l = jnp.zeros((block_q,), dtype=jnp.float32)
    acc = jnp.zeros((block_q, d), dtype=jnp.float32)

    # Online softmax over KV blocks. T is static, so this is a static loop
    # that XLA/Mosaic can pipeline (double-buffered VMEM loads on TPU).
    for kb in range(t // block_k):
        k_blk = k_ref[0, 0, kb * block_k : (kb + 1) * block_k, :].astype(jnp.float32)
        v_blk = v_ref[0, 0, kb * block_k : (kb + 1) * block_k, :].astype(jnp.float32)
        s = q @ k_blk.T * scale  # [BQ, BK] — the MXU matmul
        key_pos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = key_pos[None, :] <= q_pos[:, None]
        s = jnp.where(mask, s, _NEG_INF)

        m_new = jnp.maximum(m, s.max(axis=-1))
        correction = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])  # [BQ, BK]
        l = l * correction + p.sum(axis=-1)
        acc = acc * correction[:, None] + p @ v_blk
        m = m_new

    o_ref[0, 0] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "interpret"))
def attention(q, k, v, lengths, block_q=16, block_k=64, interpret=True):
    """Fused masked attention with grouped KV heads (Pallas).

    Args:
      q: [B, Hq, S, D] queries (the last S positions of each sequence).
      k: [B, Hkv, T, D] padded keys.
      v: [B, Hkv, T, D] padded values.
      lengths: [B] int32 valid KV length per batch element.
      block_q / block_k: VMEM tile sizes.
      interpret: must be True on CPU (Mosaic custom-calls are TPU-only).

    Returns:
      [B, Hq, S, D] attention output in q's dtype.
    """
    b, hq, s, d = q.shape
    _, hkv, t, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    # Shrink tiles to the largest divisors of S and T (odd prefill lengths
    # fall back to narrower query tiles rather than failing).
    block_q = min(block_q, s)
    while s % block_q != 0:
        block_q -= 1
    block_k = min(block_k, t)
    while t % block_k != 0:
        block_k -= 1
    scale = 1.0 / (d**0.5)

    grid = (b, hq, s // block_q)
    kernel = functools.partial(_attention_kernel, s, block_k, scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bb, h, qb: (bb,)),
            pl.BlockSpec((1, 1, block_q, d), lambda bb, h, qb: (bb, h, qb, 0)),
            pl.BlockSpec((1, 1, t, d), lambda bb, h, qb: (bb, h // group, 0, 0)),
            pl.BlockSpec((1, 1, t, d), lambda bb, h, qb: (bb, h // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda bb, h, qb: (bb, h, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, s, d), q.dtype),
        interpret=interpret,
    )(lengths, q, k, v)
