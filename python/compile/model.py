"""L2: the tiny Llama-style model (RMSNorm + RoPE + GQA + SwiGLU), written
in JAX and calling the L1 Pallas attention kernel, with an explicit KV cache
threaded through prefill/decode so the functions are pure and AOT-lowerable.

The KV cache layout is ``[layers, 2, B, T, KH, HD]`` (2 = key/value planes),
allocated at the maximum context length so all AOT shapes are static.
"""

import jax
import jax.numpy as jnp

from .configs import TinyConfig
from .kernels.attention import attention
from .kernels.ref import attention_ref, rmsnorm_ref, rope_ref, swiglu_ref


# ---- parameters --------------------------------------------------------------

def param_order(cfg: TinyConfig):
    """Canonical (name, shape) list — the export/import contract with rust."""
    h, kvd = cfg.hidden, cfg.kv_heads * cfg.head_dim
    order = [("embedding", (cfg.vocab, h))]
    for layer in range(cfg.layers):
        p = f"layers.{layer}."
        order += [
            (p + "attn_norm", (h,)),
            (p + "wq", (h, h)),
            (p + "wk", (h, kvd)),
            (p + "wv", (h, kvd)),
            (p + "wo", (h, h)),
            (p + "mlp_norm", (h,)),
            (p + "w_gate", (h, cfg.intermediate)),
            (p + "w_up", (h, cfg.intermediate)),
            (p + "w_down", (cfg.intermediate, h)),
        ]
    order += [("final_norm", (h,)), ("lm_head", (h, cfg.vocab))]
    return order


def init_params(cfg: TinyConfig, seed: int = 0):
    """Deterministic scaled-normal init. Returns a flat list of arrays in
    ``param_order`` order (the list form keeps the AOT signature simple)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_order(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params.append(jnp.ones(shape, dtype=jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = (2.0 / (fan_in + shape[-1])) ** 0.5
            params.append(
                jax.random.normal(sub, shape, dtype=jnp.float32) * std
            )
    return params


def _unpack(cfg: TinyConfig, params):
    """Flat list -> nested dict."""
    names = [n for n, _ in param_order(cfg)]
    d = dict(zip(names, params))
    layers = []
    for i in range(cfg.layers):
        p = f"layers.{i}."
        layers.append({k[len(p):]: v for k, v in d.items() if k.startswith(p)})
    return d["embedding"], layers, d["final_norm"], d["lm_head"]


def empty_cache(cfg: TinyConfig, batch: int):
    """[L, 2, B, T, KH, HD] zero-initialised KV cache."""
    return jnp.zeros(
        (cfg.layers, 2, batch, cfg.max_seq, cfg.kv_heads, cfg.head_dim),
        dtype=jnp.float32,
    )


# ---- blocks ------------------------------------------------------------------

def _attn_block(cfg, layer, x, cache_l, positions, lengths, use_kernel, is_prefill):
    """One attention block over the last S positions.

    Args:
      x: [B, S, H] normalized input.
      cache_l: [2, B, T, KH, HD] this layer's cache (already containing any
        earlier context).
      positions: [S] (prefill, shared across batch) or [B] (decode, S=1)
        absolute positions of the new tokens.
      lengths: [B] int32 total valid length *including* the new tokens.
    Returns: (attn output [B, S, H], updated cache_l).
    """
    b, s, h = x.shape
    q = (x @ layer["wq"]).reshape(b, s, cfg.heads, cfg.head_dim)
    k = (x @ layer["wk"]).reshape(b, s, cfg.kv_heads, cfg.head_dim)
    v = (x @ layer["wv"]).reshape(b, s, cfg.kv_heads, cfg.head_dim)

    # RoPE on q and k at their absolute positions.
    if is_prefill:
        pos = positions  # prefill: same positions for every batch row
        q = rope_ref(q.transpose(0, 2, 1, 3), pos, cfg.rope_theta).transpose(0, 2, 1, 3)
        k = rope_ref(k.transpose(0, 2, 1, 3), pos, cfg.rope_theta).transpose(0, 2, 1, 3)
        # Scatter into the cache at [0:S].
        cache_l = cache_l.at[0, :, :s].set(k)
        cache_l = cache_l.at[1, :, :s].set(v)
    else:
        # Decode: one token per batch row at row-specific positions.
        assert s == 1
        pos_b = positions.reshape(b, 1)  # [B, 1]
        q = jax.vmap(lambda xi, pi: rope_ref(xi, pi, cfg.rope_theta))(
            q.transpose(0, 2, 1, 3), pos_b
        ).transpose(0, 2, 1, 3)
        k = jax.vmap(lambda xi, pi: rope_ref(xi, pi, cfg.rope_theta))(
            k.transpose(0, 2, 1, 3), pos_b
        ).transpose(0, 2, 1, 3)
        bidx = jnp.arange(b)
        cache_l = cache_l.at[0, bidx, positions].set(k[:, 0])
        cache_l = cache_l.at[1, bidx, positions].set(v[:, 0])

    # Attend over the cache: [B, KH, T, HD].
    k_all = cache_l[0].transpose(0, 2, 1, 3)
    v_all = cache_l[1].transpose(0, 2, 1, 3)
    q_t = q.transpose(0, 2, 1, 3)  # [B, Hq, S, HD]
    attn_fn = attention if use_kernel else attention_ref
    out = attn_fn(q_t, k_all, v_all, lengths)  # [B, Hq, S, HD]
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h)
    return out @ layer["wo"], cache_l


def _forward(cfg, params, tokens, cache, positions, lengths, use_kernel, is_prefill):
    """Shared prefill/decode forward over the last S tokens.

    tokens: [B, S] int32; returns (logits [B, V] for the final position,
    updated cache).
    """
    embedding, layers, final_norm, lm_head = _unpack(cfg, params)
    x = embedding[tokens]  # [B, S, H]
    new_cache = []
    for i, layer in enumerate(layers):
        normed = rmsnorm_ref(x, layer["attn_norm"])
        attn_out, cache_l = _attn_block(
            cfg, layer, normed, cache[i], positions, lengths, use_kernel, is_prefill
        )
        x = x + attn_out
        normed = rmsnorm_ref(x, layer["mlp_norm"])
        x = x + swiglu_ref(normed, layer["w_gate"], layer["w_up"], layer["w_down"])
        new_cache.append(cache_l)
    x = rmsnorm_ref(x, final_norm)
    logits = x[:, -1, :] @ lm_head  # [B, V]
    return logits, jnp.stack(new_cache)


def prefill(cfg: TinyConfig, params, tokens, cache, use_kernel=True):
    """Prefill a single request (B=1): tokens [1, S] starting at position 0.

    Returns (logits [1, V], cache with positions [0, S) filled).
    """
    _, s = tokens.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    lengths = jnp.full((tokens.shape[0],), s, dtype=jnp.int32)
    return _forward(cfg, params, tokens, cache, positions, lengths, use_kernel, True)


def decode_step(cfg: TinyConfig, params, tokens, cache, positions, use_kernel=True):
    """One decode step for a batch of slots.

    Args:
      tokens: [B] int32 last generated token per slot.
      cache: [L, 2, B, T, KH, HD].
      positions: [B] int32 — index the new token is written at (= current
        valid length before this step).

    Returns (logits [B, V], updated cache).
    """
    b = tokens.shape[0]
    tokens2 = tokens.reshape(b, 1)
    lengths = positions + 1
    return _forward(cfg, params, tokens2, cache, positions, lengths, use_kernel, False)


def greedy_generate(cfg, params, prompt, steps, use_kernel=True):
    """Reference greedy generation (test/demo helper, python-side only)."""
    cache = empty_cache(cfg, 1)
    logits, cache = prefill(cfg, params, prompt.reshape(1, -1), cache, use_kernel)
    out = []
    pos = prompt.shape[-1]
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for _ in range(steps):
        out.append(int(tok[0]))
        logits, cache = decode_step(
            cfg, params, tok, cache, jnp.array([pos], dtype=jnp.int32), use_kernel
        )
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos += 1
    return out
