"""Model and AOT-bucket configuration shared by the compile pipeline.

The tiny Llama-style model served end-to-end by the rust engine. Its
architecture mirrors `rust/src/perf_model/model_spec.rs::ModelSpec::tiny`
(but with a reduced vocab so the exported weights stay small).
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class TinyConfig:
    """TinyLlama-5M: a real (untrained) Llama3-architecture model."""

    vocab: int = 4096
    hidden: int = 256
    layers: int = 4
    heads: int = 8
    kv_heads: int = 4
    intermediate: int = 688
    max_seq: int = 256
    rope_theta: float = 10000.0

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    def param_count(self) -> int:
        h, v, i = self.hidden, self.vocab, self.intermediate
        per_layer = (
            h * h  # wq
            + 2 * h * (self.kv_heads * self.head_dim)  # wk, wv
            + h * h  # wo
            + 3 * h * i  # w_gate, w_up, w_down
            + 2 * h  # norms
        )
        return v * h + self.layers * per_layer + h + v * h


@dataclass(frozen=True)
class AotBuckets:
    """Fixed shapes compiled ahead of time.

    The rust coordinator picks the smallest bucket that fits; prefill runs
    one request at a time (chunked into the sequence bucket), decode runs a
    whole continuous batch per step.
    """

    prefill_seq: tuple = (16, 32, 64, 128)
    decode_batch: tuple = (1, 2, 4, 8)
    max_seq: int = 256


DEFAULT_CONFIG = TinyConfig()
DEFAULT_BUCKETS = AotBuckets()
