"""AOT export: lower the L2 model to HLO **text** artifacts the rust runtime
loads via the PJRT C API, plus the weight blob and a manifest.

HLO text (not serialized HloModuleProto) is the interchange format: jax ≥0.5
emits protos with 64-bit instruction ids which the image's xla_extension
0.5.1 rejects; the text parser reassigns ids (see /opt/xla-example/README).

Outputs (under --out-dir, default ../artifacts):
  prefill_s{S}.hlo.txt     one per prefill sequence bucket, batch 1
  decode_b{B}.hlo.txt      one per decode batch bucket
  weights.bin              all parameters, f32 little-endian, in
                           `model.param_order` order
  manifest.json            shapes, buckets, parameter table, input order

Every executable takes (params..., tokens, cache[, positions]) and returns
(logits, new_cache). Python runs ONCE at build time; the rust binary is
self-contained afterwards.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import DEFAULT_BUCKETS, DEFAULT_CONFIG
from . import model as m


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export(out_dir: str, seed: int = 0, use_kernel: bool = True, buckets=None) -> dict:
    cfg = DEFAULT_CONFIG
    buckets = buckets or DEFAULT_BUCKETS
    os.makedirs(out_dir, exist_ok=True)

    params = m.init_params(cfg, seed)
    order = m.param_order(cfg)
    param_specs = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params]

    manifest = {
        "model": {
            "vocab": cfg.vocab,
            "hidden": cfg.hidden,
            "layers": cfg.layers,
            "heads": cfg.heads,
            "kv_heads": cfg.kv_heads,
            "head_dim": cfg.head_dim,
            "intermediate": cfg.intermediate,
            "max_seq": cfg.max_seq,
            "param_count": cfg.param_count(),
        },
        "seed": seed,
        "use_kernel": bool(use_kernel),
        "params": [],
        "prefill": [],
        "decode": [],
        # Input convention for every executable:
        #   [param_0 .. param_{P-1}, tokens, cache, (positions for decode)]
        "input_order": "params,tokens,cache[,positions]",
    }

    # ---- weights ------------------------------------------------------------
    offset = 0
    import numpy as np

    blob_path = os.path.join(out_dir, "weights.bin")
    with open(blob_path, "wb") as f:
        for (name, shape), p in zip(order, params):
            arr = np.asarray(p, dtype="<f4")
            f.write(arr.tobytes())
            manifest["params"].append(
                {"name": name, "shape": list(shape), "offset": offset}
            )
            offset += arr.size
    manifest["weights_f32_count"] = offset

    # ---- prefill buckets ------------------------------------------------------
    for s in buckets.prefill_seq:
        def prefill_fn(params, tokens, cache, _s=s):
            return m.prefill(cfg, list(params), tokens, cache, use_kernel=use_kernel)

        tokens_spec = jax.ShapeDtypeStruct((1, s), jnp.int32)
        cache_spec = jax.ShapeDtypeStruct(
            (cfg.layers, 2, 1, cfg.max_seq, cfg.kv_heads, cfg.head_dim), jnp.float32
        )
        lowered = jax.jit(prefill_fn).lower(tuple(param_specs), tokens_spec, cache_spec)
        text = to_hlo_text(lowered)
        fname = f"prefill_s{s}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["prefill"].append({"seq": s, "file": fname})

    # ---- decode buckets ---------------------------------------------------------
    for b in buckets.decode_batch:
        def decode_fn(params, tokens, cache, positions):
            return m.decode_step(
                cfg, list(params), tokens, cache, positions, use_kernel=use_kernel
            )

        tokens_spec = jax.ShapeDtypeStruct((b,), jnp.int32)
        cache_spec = jax.ShapeDtypeStruct(
            (cfg.layers, 2, b, cfg.max_seq, cfg.kv_heads, cfg.head_dim), jnp.float32
        )
        pos_spec = jax.ShapeDtypeStruct((b,), jnp.int32)
        lowered = jax.jit(decode_fn).lower(
            tuple(param_specs), tokens_spec, cache_spec, pos_spec
        )
        text = to_hlo_text(lowered)
        fname = f"decode_b{b}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["decode"].append({"batch": b, "file": fname})

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file marker path")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--no-kernel",
        action="store_true",
        help="lower with the pure-jnp reference attention instead of Pallas",
    )
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out is not None:
        out_dir = os.path.dirname(args.out) or "."
    manifest = export(out_dir, seed=args.seed, use_kernel=not args.no_kernel)
    n_files = len(manifest["prefill"]) + len(manifest["decode"])
    print(
        f"wrote {n_files} HLO artifacts + weights.bin "
        f"({manifest['weights_f32_count'] * 4 / 1e6:.1f} MB) to {out_dir}"
    )
    if args.out is not None:
        # Makefile stamp compatibility: touch the marker file.
        with open(args.out, "w") as f:
            f.write("see manifest.json\n")


if __name__ == "__main__":
    main()
