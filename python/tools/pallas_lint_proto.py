#!/usr/bin/env python3
"""Reference prototype of `pallas-lint` (rust/src/analysis/).

The offline container that grows this repo has no Rust toolchain, so —
exactly like the simplex core (python/solver_harness/factor_simplex.py was
validated against scipy before the Rust transcription) — the analyzer's
semantics were prototyped here first: masking, tokenization, test-region
detection, zone classification, the six rules, suppression directives, and
the baseline ratchet. The Rust implementation in rust/src/analysis/ is a
line-for-line transcription of these semantics; the fixture unit tests on
the Rust side pin the same behaviours this prototype was exercised with.

Usage:
    python3 python/tools/pallas_lint_proto.py [--root rust/src]
        [--baseline rust/analysis/baseline.json] [--update-baseline] [-v]

Exit code 1 when any violation is not frozen by the baseline.
"""

import json
import os
import sys

DETERMINISTIC = [
    "milp/",
    "sim/engine.rs",
    "sim/timeline.rs",
    "workload/stream.rs",
    "workload/drift.rs",
    "cloud/faults.rs",
    "util/rng.rs",
    "sched/binary_search.rs",
]
HOT = ["milp/bounds.rs", "milp/factor.rs", "milp/dense.rs", "sim/engine.rs"]

RATCHETABLE = {"A001", "F001", "P001"}
ALL_RULES = ["D001", "D002", "D003", "A001", "F001", "P001", "L001"]

FLOAT_CONSTS = {
    "INFINITY", "NEG_INFINITY", "NAN", "MAX", "MIN", "EPSILON", "MIN_POSITIVE",
}


def classify(rel):
    det = any(
        rel.startswith(e) if e.endswith("/") else rel == e for e in DETERMINISTIC
    )
    hot = rel in HOT
    return det, hot


# ---- lexer ----------------------------------------------------------------

def is_ident_start(c):
    return c.isalpha() and c.isascii() or c == "_"


def is_ident_continue(c):
    return (c.isalnum() and c.isascii()) or c == "_"


def scan(source):
    """Return (lines, masked, comments) mirroring lexer::FileScan::scan."""
    lines, masked, comments = [], [], []
    state = ("code",)
    for raw in source.split("\n"):
        chars = list(raw)
        n = len(chars)
        out = []
        comment = []
        i = 0
        while i < n:
            kind = state[0]
            if kind == "block":
                depth = state[1]
                if chars[i] == "/" and i + 1 < n and chars[i + 1] == "*":
                    state = ("block", depth + 1)
                    comment.append("/*")
                    out += [" ", " "]
                    i += 2
                elif chars[i] == "*" and i + 1 < n and chars[i + 1] == "/":
                    state = ("code",) if depth == 1 else ("block", depth - 1)
                    comment.append("*/")
                    out += [" ", " "]
                    i += 2
                else:
                    comment.append(chars[i])
                    out.append("\t" if chars[i] == "\t" else " ")
                    i += 1
            elif kind == "str":
                if chars[i] == "\\" and i + 1 < n:
                    out += [" ", " "]
                    i += 2
                elif chars[i] == '"':
                    state = ("code",)
                    out.append(" ")
                    i += 1
                else:
                    out.append("\t" if chars[i] == "\t" else " ")
                    i += 1
            elif kind == "rawstr":
                hashes = state[1]
                if chars[i] == '"':
                    have = 0
                    for c in chars[i + 1 : i + 1 + hashes]:
                        if c == "#":
                            have += 1
                        else:
                            break
                    if have == hashes:
                        state = ("code",)
                        out += [" "] * (hashes + 1)
                        i += 1 + hashes
                        continue
                out.append("\t" if chars[i] == "\t" else " ")
                i += 1
            else:  # code
                c = chars[i]
                if c == "/" and i + 1 < n and chars[i + 1] == "/":
                    comment.append("".join(chars[i:]))
                    out += [" "] * (n - i)
                    i = n
                elif c == "/" and i + 1 < n and chars[i + 1] == "*":
                    state = ("block", 1)
                    comment.append("/*")
                    out += [" ", " "]
                    i += 2
                elif c == '"':
                    state = ("str",)
                    out.append(" ")
                    i += 1
                elif c == "'":
                    if i + 1 < n and chars[i + 1] == "\\":
                        j = i + 2
                        while j < n and chars[j] != "'":
                            j += 1
                        end = min(j + 1, n)
                        out += [" "] * (end - i)
                        i = end
                    elif i + 2 < n and chars[i + 2] == "'" and chars[i + 1] != "'":
                        out += [" ", " ", " "]
                        i += 3
                    else:
                        out.append("'")
                        i += 1
                elif is_ident_start(c):
                    j = i + 1
                    while j < n and is_ident_continue(chars[j]):
                        j += 1
                    ident = "".join(chars[i:j])
                    if ident in ("r", "b", "br"):
                        k = j
                        hashes = 0
                        while k < n and chars[k] == "#":
                            hashes += 1
                            k += 1
                        if k < n and chars[k] == '"':
                            if ident == "b" and hashes == 0:
                                state = ("str",)
                            else:
                                state = ("rawstr", hashes)
                            out += [" "] * (k + 1 - i)
                            i = k + 1
                            continue
                    out += chars[i:j]
                    i = j
                else:
                    out.append(c)
                    i += 1
        lines.append(raw)
        masked.append("".join(out))
        comments.append("".join(comment))
    return lines, masked, comments


INT_SUFFIXES = {
    "u8", "u16", "u32", "u64", "u128", "usize",
    "i8", "i16", "i32", "i64", "i128", "isize",
}
MULTI_PUNCT = [
    "::", "==", "!=", "<=", ">=", "->", "=>", "..", "&&", "||",
    "+=", "-=", "*=", "/=",
]


def lex_number(chars):
    n = len(chars)
    i = 1
    is_float = False
    if chars[0] == "0" and i < n and chars[i] in "xob":
        i += 1
        while i < n and (chars[i].isalnum() or chars[i] == "_"):
            i += 1
        return i, False
    while i < n and (chars[i].isdigit() or chars[i] == "_"):
        i += 1
    if i < n and chars[i] == ".":
        nxt = chars[i + 1] if i + 1 < n else None
        continues = nxt is None or nxt.isdigit() or not (is_ident_start(nxt) or nxt == ".")
        if continues:
            is_float = True
            i += 1
            while i < n and (chars[i].isdigit() or chars[i] == "_"):
                i += 1
    if i < n and chars[i] in "eE":
        j = i + 1
        if j < n and chars[j] in "+-":
            j += 1
        if j < n and chars[j].isdigit():
            is_float = True
            i = j
            while i < n and (chars[i].isdigit() or chars[i] == "_"):
                i += 1
    if i < n and is_ident_start(chars[i]):
        j = i
        while j < n and is_ident_continue(chars[j]):
            j += 1
        suffix = "".join(chars[i:j])
        if suffix in ("f32", "f64"):
            is_float = True
            i = j
        elif suffix in INT_SUFFIXES:
            i = j
    return i, is_float


def tokenize(masked):
    toks = []  # (kind, text_or_isfloat, line, col, len)
    for lineno, line in enumerate(masked):
        chars = list(line)
        n = len(chars)
        i = 0
        while i < n:
            c = chars[i]
            if c.isspace():
                i += 1
            elif is_ident_start(c):
                j = i + 1
                while j < n and is_ident_continue(chars[j]):
                    j += 1
                toks.append(("ident", "".join(chars[i:j]), lineno, i, j - i))
                i = j
            elif c.isdigit():
                ln, is_float = lex_number(chars[i:])
                toks.append(("num", is_float, lineno, i, ln))
                i += ln
            else:
                two = "".join(chars[i : i + 2])
                if two in MULTI_PUNCT:
                    toks.append(("punct", two, lineno, i, 2))
                    i += 2
                else:
                    toks.append(("punct", c, lineno, i, 1))
                    i += 1
    return toks


# ---- test regions ---------------------------------------------------------

def item_end(masked, start):
    depth = 0
    seen_brace = False
    for off in range(start, len(masked)):
        for ch in masked[off]:
            if ch == "{":
                depth += 1
                seen_brace = True
            elif ch == "}":
                depth -= 1
                if seen_brace and depth == 0:
                    return off
            elif ch == ";" and not seen_brace and depth == 0:
                return off
    return len(masked) - 1


def test_regions(masked):
    n = len(masked)
    is_test = [False] * n
    line = 0
    while line < n:
        code = masked[line].strip()
        if code.startswith("#[cfg(test)]") or code.startswith("#[test]"):
            end = item_end(masked, line)
            for l in range(line, min(end, n - 1) + 1):
                is_test[l] = True
            line = end + 1
        else:
            line += 1
    return is_test


# ---- directives -----------------------------------------------------------

def balanced_paren(s):
    depth = 1
    for i, c in enumerate(s):
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                return s[:i]
    return None


def directive_target(masked, lineno):
    if masked[lineno].strip():
        return lineno
    for l in range(lineno + 1, len(masked)):
        if masked[l].strip():
            return l
    return lineno


def parse_directives(rel, comments, masked):
    dirs, diags = [], []
    for lineno, comment in enumerate(comments):
        # Doc comments (///, //!, /** , /*!) are documentation *about* the
        # directive syntax, never directives themselves.
        stripped = comment.lstrip()
        if stripped.startswith(("///", "//!", "/**", "/*!")):
            continue
        rest = comment
        while True:
            pos = rest.find("pallas-lint:")
            if pos < 0:
                break
            after = rest[pos + len("pallas-lint:"):]
            body = after.lstrip()
            if body.startswith("allow("):
                inner = balanced_paren(body[len("allow("):])
                if inner is None:
                    diags.append(("L001", rel, lineno + 1, "unterminated allow("))
                elif "," not in inner or not inner.split(",", 1)[1].strip():
                    diags.append(("L001", rel, lineno + 1, "allow needs a reason"))
                else:
                    rule = inner.split(",", 1)[0].strip()
                    if rule not in ALL_RULES:
                        diags.append(("L001", rel, lineno + 1, f"unknown rule {rule}"))
                    else:
                        dirs.append({
                            "rule": rule,
                            "target": directive_target(masked, lineno),
                            "at": lineno,
                            "used": False,
                        })
            else:
                diags.append(("L001", rel, lineno + 1, "unrecognised directive"))
            rest = after
    return dirs, diags


# ---- rules ----------------------------------------------------------------

def check_file(rel, source):
    lines, masked, comments = scan(source)
    toks = tokenize(masked)
    is_test = test_regions(masked)
    det, hot = classify(rel)
    dirs, diags = parse_directives(rel, comments, masked)

    def live(t):
        return not is_test[t[2]]

    def comment_near(line, above, needle):
        lo = max(0, line - above)
        return any(needle in comments[l] for l in range(lo, line + 1))

    for i, t in enumerate(toks):
        kind, val, line, col, ln = t
        if kind == "ident" and live(t):
            if det and val in ("HashMap", "HashSet", "RandomState", "hash_map", "hash_set"):
                diags.append(("D001", rel, line + 1, f"`{val}` in deterministic zone"))
            if det:
                nxt_path = (
                    i + 2 < len(toks)
                    and toks[i + 1][:2] == ("punct", "::")
                    and toks[i + 2][0] == "ident"
                )
                flagged = (
                    (val == "Instant" and nxt_path and toks[i + 2][1] == "now")
                    or val == "SystemTime"
                    or (val == "thread" and nxt_path and toks[i + 2][1] == "current")
                )
                if flagged:
                    diags.append(("D002", rel, line + 1, f"`{val}` wall-clock/thread read"))
            if rel != "util/rng.rs" and val in (
                "thread_rng", "ThreadRng", "from_entropy", "OsRng", "getrandom", "EntropyRng",
            ):
                diags.append(("D003", rel, line + 1, f"`{val}` entropy RNG"))
            if (
                val in ("Relaxed", "Acquire", "Release", "AcqRel")
                and i > 0
                and toks[i - 1][:2] == ("punct", "::")
                and not comment_near(line, 3, "ordering:")
            ):
                diags.append(("A001", rel, line + 1, f"::{val} without // ordering:"))
            if val == "unwrap" and i > 0 and toks[i - 1][:2] == ("punct", ".") and \
                    i + 1 < len(toks) and toks[i + 1][:2] == ("punct", "("):
                diags.append(("P001", rel, line + 1, "unwrap()"))
            if val in ("panic", "unreachable", "todo", "unimplemented") and \
                    i + 1 < len(toks) and toks[i + 1][:2] == ("punct", "!"):
                diags.append(("P001", rel, line + 1, f"{val}!"))
        elif kind == "punct" and val in ("==", "!=") and live(t):
            def is_float_tok(k):
                return 0 <= k < len(toks) and toks[k][0] == "num" and toks[k][1]

            def const_before(k):
                return (
                    k >= 3
                    and toks[k - 1][0] == "ident" and toks[k - 1][1] in FLOAT_CONSTS
                    and toks[k - 2][:2] == ("punct", "::")
                    and toks[k - 3][0] == "ident" and toks[k - 3][1] in ("f32", "f64")
                )

            def const_after(k):
                return (
                    k + 3 < len(toks)
                    and toks[k + 1][0] == "ident" and toks[k + 1][1] in ("f32", "f64")
                    and toks[k + 2][:2] == ("punct", "::")
                    and toks[k + 3][0] == "ident" and toks[k + 3][1] in FLOAT_CONSTS
                )

            lhs = i > 0 and (is_float_tok(i - 1) or const_before(i))
            rhs = is_float_tok(i + 1) or const_after(i) or (
                i + 2 < len(toks) and toks[i + 1][:2] == ("punct", "-") and is_float_tok(i + 2)
            )
            if lhs or rhs:
                diags.append(("F001", rel, line + 1, f"bare {val} vs float literal"))

    violations, suppressed, notes = [], 0, []
    for d in diags:
        rule, _, line1, _ = d
        hit = False
        if rule != "L001":
            for dr in dirs:
                if dr["rule"] == rule and dr["target"] == line1 - 1:
                    dr["used"] = True
                    hit = True
                    break
        if hit:
            suppressed += 1
        else:
            violations.append(d)
    for dr in dirs:
        if not dr["used"]:
            notes.append(f"{rel}:{dr['at'] + 1}: unused allow({dr['rule']})")
    violations.sort(key=lambda d: (d[2],))
    return violations, suppressed, notes


# ---- driver ---------------------------------------------------------------

def collect(root):
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()
        for f in sorted(filenames):
            if f.endswith(".rs"):
                out.append(os.path.join(dirpath, f))
    return sorted(out)


def main():
    argv = sys.argv[1:]
    root = "rust/src"
    baseline_path = "rust/analysis/baseline.json"
    update = "-u" in argv or "--update-baseline" in argv
    verbose = "-v" in argv
    if "--root" in argv:
        root = argv[argv.index("--root") + 1]
    if "--baseline" in argv:
        baseline_path = argv[argv.index("--baseline") + 1]

    all_v, suppressed, notes = [], 0, []
    files = collect(root)
    for path in files:
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        with open(path, encoding="utf-8") as fh:
            src = fh.read()
        v, s, n = check_file(rel, src)
        all_v += v
        suppressed += s
        notes += n

    counts = {}
    for rule, rel, line, msg in all_v:
        counts.setdefault(rule, {}).setdefault(rel, 0)
        counts[rule][rel] += 1

    if update:
        doc = {
            "counts": {
                r: dict(sorted(fs.items()))
                for r, fs in sorted(counts.items())
                if r in RATCHETABLE
            },
            "version": 1,
        }
        os.makedirs(os.path.dirname(baseline_path), exist_ok=True)
        with open(baseline_path, "w", encoding="utf-8") as fh:
            json.dump(doc, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"baseline updated: {baseline_path}")

    base = {}
    if os.path.exists(baseline_path):
        with open(baseline_path, encoding="utf-8") as fh:
            base = json.load(fh).get("counts", {})

    failures = 0
    for rule, fs in sorted(counts.items()):
        for rel, cnt in sorted(fs.items()):
            allowed = base.get(rule, {}).get(rel, 0) if rule in RATCHETABLE else 0
            if cnt > allowed:
                failures += cnt - allowed
                print(f"FAIL {rule} {rel}: {cnt} found, {allowed} frozen")
                if verbose:
                    for r, f2, line, msg in all_v:
                        if r == rule and f2 == rel:
                            print(f"    {f2}:{line}: {msg}")
    for n in notes:
        print("note:", n)
    per_rule = {r: sum(fs.values()) for r, fs in counts.items()}
    summary = " ".join(f"{r}={per_rule.get(r, 0)}" for r in ALL_RULES)
    print(
        f"pallas-lint(proto): {len(files)} files, {len(all_v)} violation(s), "
        f"{suppressed} allowed inline [{summary}]"
    )
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
